"""CVMM hot-path micro-benchmark: fused vs unfused pallas vs ragged.

Times the dropless expert MLP (the paper's CVMM pipeline, Eq. 11) at a fixed
routing and emits ``BENCH_cvmm.json``: us/call for forward and forward+backward
per impl, plus an analytic estimate of the HBM bytes moved through materialized
intermediates — the quantity the fused pipeline attacks (one layout plan, no
gathered (N*K, d) copy, no separate activation / gate passes, no re-pad in
backward).

On CPU the pallas kernels run in interpret mode, so absolute numbers are not
TPU numbers; the comparison fused-vs-unfused and the bytes model are the
tracked signals. Run:  PYTHONPATH=src python -m benchmarks.bench_cvmm [--out F]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels import ops
from repro.kernels.cvmm import LANE, TM

# Bench scale: one MoE layer's worth of tokens, kept small enough that
# interpret-mode kernels finish in seconds on a single CPU core.
N_TOKENS = 256
D_MODEL = 128
N_EXPERTS = 4
EXPERT_SIZE = 128
K = 2
GLU = True
ITERS = 10


def _setup(dtype=jnp.float32):
    key = jax.random.PRNGKey(0)
    kx, ki, kg, k1, k2, k3 = jax.random.split(key, 6)
    xf = jax.random.normal(kx, (N_TOKENS, D_MODEL), jnp.float32).astype(dtype)
    idx = jax.random.randint(ki, (N_TOKENS, K), 0, N_EXPERTS)
    gates = jax.nn.softmax(jax.random.normal(kg, (N_TOKENS, K), jnp.float32), -1)
    w1 = (0.3 * jax.random.normal(k1, (N_EXPERTS, D_MODEL, EXPERT_SIZE))).astype(dtype)
    w1g = (0.3 * jax.random.normal(k2, (N_EXPERTS, D_MODEL, EXPERT_SIZE))).astype(dtype)
    w2 = (0.3 * jax.random.normal(k3, (N_EXPERTS, EXPERT_SIZE, D_MODEL))).astype(dtype)
    return xf, idx, gates, w1, w1g, w2


def _mlp(impl: str):
    """The sort-path expert MLP at a fixed routing, per impl — mirroring
    core/moe.py's dispatch exactly so the tracked fused-vs-unfused ratio
    compares against the REAL production unfused path (one shared plan via
    cvmm_planned, not a per-GEMM layout re-derivation)."""
    def f(xf, idx, gates, w1, w1g, w2):
        n = xf.shape[0]
        if impl.startswith("pallas"):
            plan = ops.make_moe_plan(idx, gates, n, N_EXPERTS)
            if impl == "pallas_fused":
                return ops.moe_mlp_fused(xf, plan, w1, w2, w1g if GLU else None,
                                         activation="relu")
            interpret = ops._impl_interpret(impl)
            src = jnp.repeat(jnp.arange(n), K)[plan.perm]
            xs = xf[src]
            h = ops.cvmm_planned(xs, plan, w1, interpret=interpret)
            u = jax.nn.relu(h)
            if GLU:
                u = u * ops.cvmm_planned(xs, plan, w1g, interpret=interpret)
            y = ops.cvmm_planned(u, plan, w2, interpret=interpret)
            y = y * gates.reshape(-1)[plan.perm][:, None].astype(y.dtype)
            return jnp.zeros_like(xf).at[src].add(y)
        e_flat = idx.reshape(-1)
        g_flat = gates.reshape(-1)
        tok = jnp.repeat(jnp.arange(n), K)
        perm = jnp.argsort(e_flat, stable=True)
        gs = jnp.bincount(e_flat, length=N_EXPERTS)
        xs = xf[tok[perm]]
        h = ops.cvmm(xs, gs, w1, impl=impl)
        u = jax.nn.relu(h)
        if GLU:
            u = u * ops.cvmm(xs, gs, w1g, impl=impl)
        y = ops.cvmm(u, gs, w2, impl=impl)
        y = y * g_flat[perm][:, None].astype(y.dtype)
        return jnp.zeros_like(xf).at[tok[perm]].add(y)
    return f


def _time(fn, args, iters=ITERS):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _est_bytes(impl: str, itemsize: int = 4) -> dict:
    """Materialized-intermediate bytes for one fwd(+bwd), analytic model.

    Counts only buffers that round-trip through HBM *between* compute stages
    (the traffic fusion removes); weights/activations read in place are common
    to every impl and excluded."""
    nk = N_TOKENS * K
    m_pad = round_up(nk, TM) + N_EXPERTS * TM
    d, g = round_up(D_MODEL, LANE), round_up(EXPERT_SIZE, LANE)
    row = itemsize
    n_w1 = 2 if GLU else 1
    if impl == "pallas_fused":
        # fwd: u (w1 out, act+GLU applied in-kernel) + y_pad (gate in-kernel)
        fwd = m_pad * g * row + m_pad * d * row
        # training fwd additionally writes h(/hg) in the same grid pass (no
        # recompute GEMMs in bwd); bwd: dy_pad + x_pad (the single layout
        # materialization of the backward) + t0 + dx_pad
        bwd = (n_w1 * m_pad * g + 2 * m_pad * d + m_pad * g + m_pad * d) * row
    elif impl in ("pallas", "pallas_interpret"):
        # fwd: gathered xs + x_pad scatter + per-GEMM (pad in, out, unpad) +
        # act + GLU mult + gate mult as separate XLA passes
        fwd = (nk * d + m_pad * d                       # gather + pad
               + n_w1 * (m_pad * g + nk * g)            # w1(+w1g) out (+unpad)
               + nk * g                                 # act/GLU result
               + m_pad * g                              # u re-pad for w2
               + m_pad * d + nk * d + nk * d) * row     # w2 out, unpad, gate
        # bwd mirrors fwd: g_pad per GEMM + dx_pad/unpad + dw accumulators
        bwd = (3 * (m_pad * d + m_pad * g) + 2 * nk * d + 2 * nk * g) * row
    else:  # ragged
        fwd = (nk * d + n_w1 * nk * g + nk * g + nk * d + nk * d) * row
        bwd = (3 * (nk * d + nk * g)) * row
    return {"fwd": int(fwd), "fwd_bwd": int(fwd + bwd)}


def run(out_path: str = "BENCH_cvmm.json", iters: int = ITERS):
    args = _setup()
    results = {}
    for impl in ("ragged", "pallas", "pallas_fused"):
        f = _mlp(impl)
        fwd = jax.jit(f)
        probe = lambda *a: f(*a).astype(jnp.float32).sum()
        grad = jax.jit(jax.grad(probe, argnums=(0, 2, 3, 4, 5)))
        fwd_us = _time(fwd, args, iters)
        fwd_bwd_us = _time(grad, args, iters)
        results[impl] = {
            "fwd_us": round(fwd_us, 1),
            "fwd_bwd_us": round(fwd_bwd_us, 1),
            "est_intermediate_bytes": _est_bytes(impl),
        }
    payload = {
        "config": {"n_tokens": N_TOKENS, "d_model": D_MODEL,
                   "n_experts": N_EXPERTS, "expert_size": EXPERT_SIZE,
                   "k": K, "glu": GLU, "iters": iters,
                   "backend": jax.default_backend(),
                   "note": "pallas impls run in interpret mode off-TPU"},
        "results": results,
        "fused_speedup_vs_pallas": {
            "fwd": round(results["pallas"]["fwd_us"]
                         / max(results["pallas_fused"]["fwd_us"], 1e-9), 3),
            "fwd_bwd": round(results["pallas"]["fwd_bwd_us"]
                             / max(results["pallas_fused"]["fwd_bwd_us"], 1e-9), 3),
        },
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = [f"cvmm/{impl}_fwd,{r['fwd_us']},"
            f"est_bytes={r['est_intermediate_bytes']['fwd']}"
            for impl, r in results.items()]
    rows += [f"cvmm/{impl}_fwd_bwd,{r['fwd_bwd_us']},"
             f"est_bytes={r['est_intermediate_bytes']['fwd_bwd']}"
             for impl, r in results.items()]
    rows.append(f"# wrote {out_path}; fused/unfused fwd+bwd speedup "
                f"{payload['fused_speedup_vs_pallas']['fwd_bwd']}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_cvmm.json")
    ap.add_argument("--iters", type=int, default=ITERS)
    args = ap.parse_args()
    for row in run(args.out, args.iters):
        print(row)


if __name__ == "__main__":
    main()
