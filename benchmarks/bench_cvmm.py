"""CVMM hot-path micro-benchmark: fused vs unfused pallas vs ragged.

Times the dropless expert MLP (the paper's CVMM pipeline, Eq. 11) at a fixed
routing and emits ``BENCH_cvmm.json``: us/call for forward, forward+backward
and the directly-timed backward-only (vjp) wall clock per impl, plus an
analytic estimate
of the HBM bytes moved through materialized intermediates — the quantity the
fused pipeline attacks (one layout plan, no gathered (N*K, d) copy forward OR
backward, no separate activation / gate passes, no re-pad in backward) — and
the plan's DMA-descriptor counts (run-batched chunks vs the retired
one-copy-per-row scheme).

``fused_speedup_vs_pallas`` carries three CI-gated signals: ``fwd`` and
``fwd_bwd`` (>= 1.0), plus ``bwd`` — the directly-timed (vjp) backward that
isolates the streamed gather-free dW/dX path so a regression there cannot
hide behind a fast forward pass. On CPU the interpret-mode kernels serialize
the DMA overlap the streamed backward exists for, so ``bwd`` reads ~1.0
there (TPU is where the overlap pays); CI gates it as a regression tripwire
(>= 0.85), not a speedup claim.

Three configs are measured:

  base     one MoE layer's worth of tokens, small enough that interpret-mode
           kernels finish in seconds on a single CPU core; fwd AND fwd+bwd.
           Its ``fused_speedup_vs_pallas`` is the CI-gated signal (>= 1.0).
  large_n  a token count PAST the retired whole-x VMEM residency boundary
           (``cvmm.legacy_whole_x_rows``) — the regime the streamed
           double-buffered row-DMA gather exists for; before the streaming
           rewrite ``fused_supported`` rejected it and the fused path silently
           fell back. Forward-only and fewer iters to keep the quick bench
           fast; recorded under ``large_n`` in the JSON.
  pkm      the unified layer's weighted value aggregation (PR 5): PKM-style
           H*K-of-n_values selection through GatherPlan + the streamed gather
           kernels vs the dense (N, S, d) take+einsum it replaced. Recorded
           as ``pkm_speedup_vs_dense`` and CI-gated with interpret-mode
           TRIPWIRE semantics (like the ``bwd`` gate): on CPU the serialized
           DMA pipeline loses to XLA's fused gather, so the thresholds only
           trip on real regressions of the planned path.
  pkm_large  PKM aggregation at a scale where coalescing matters (PR 7):
           >= 64k values (n_subkeys=256), a realistic token batch, and a
           duplicate-heavy hot-set routing (90% of selections land on 1k hot
           values — the regime usage-skewed PKM training produces). Measures
           the DEDUP plan (``ops.make_dedup_gather_plan`` + the compacted
           streamed gather, the production ``weighted_value_sum`` lowering)
           vs the dense reference, and records its ``dma_descriptors``:
           ``batching_factor`` here is the CI-GATED coalescing signal
           (>= 4.0) — the dedup/sorted plan must beat one-DMA-per-selection
           by 4x where the old flat plan flat-lined at 1.003.

On CPU the pallas kernels run in interpret mode, so absolute numbers are not
TPU numbers; the comparison fused-vs-unfused and the bytes model are the
tracked signals. Run:  PYTHONPATH=src python -m benchmarks.bench_cvmm [--out F]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels import autotune, cvmm, ops
from repro.kernels.cvmm import LANE, TM, legacy_whole_x_rows

ITERS = 10


def _moe_tile_report(cfg: "BenchConfig") -> dict:
    """The tile choices this config's kernels will actually launch with, plus
    tuner provenance ("heuristic" = static first-fit, "tuned" = cache/bench
    winner) — recorded per config so CI can diff tile decisions across runs
    (the determinism gate) and tuned runs are auditable."""
    fused = ops.fused_mlp_tiles(cfg.d_model, cfg.expert_size, glu=cfg.glu)
    pw1 = ops.planned_call_tiles(cfg.d_model, cfg.expert_size)
    pw2 = ops.planned_call_tiles(cfg.expert_size, cfg.d_model)
    return {"fused": None if fused is None else fused._asdict(),
            "planned_w1": None if pw1 is None else pw1._asdict(),
            "planned_w2": None if pw2 is None else pw2._asdict()}


def _gather_tile_report(d_model: int, itemsize: int = 4) -> dict:
    dec = autotune.gather_tiles(round_up(d_model, LANE), itemsize,
                                budget=cvmm.VMEM_BUDGET)
    return {"gather": dec.tiles, "provenance": dec.provenance}


def _tune_report() -> dict:
    """Process-wide tuner telemetry for this bench run. ``microbench_calls``
    is the CI cache-hit signal: a --tune run against a warm cache must report
    0 here (pure cache hit, nothing re-measured)."""
    return {"enabled": autotune.enabled(),
            "backend": jax.default_backend(),
            "vmem_budget": cvmm.VMEM_BUDGET,
            "cache_path": autotune.cache_path() if autotune.enabled()
            else None,
            **autotune.STATS}


class BenchConfig(NamedTuple):
    n_tokens: int
    d_model: int
    n_experts: int
    expert_size: int
    k: int
    glu: bool


# Bench scale: one MoE layer's worth of tokens, kept small enough that
# interpret-mode kernels finish in seconds on a single CPU core.
BASE = BenchConfig(n_tokens=256, d_model=128, n_experts=4, expert_size=128,
                   k=2, glu=True)


def _large_n_config() -> BenchConfig:
    """Smallest config past the retired whole-x VMEM boundary (fp32, no GLU,
    K=1 to keep interpret-mode wall clock tolerable)."""
    old = legacy_whole_x_rows(k_pad=128, bytes_per_el=4, n_weights=1, n_out=2)
    return BenchConfig(n_tokens=old + TM, d_model=128, n_experts=4,
                       expert_size=128, k=1, glu=False)


class PkmBenchConfig(NamedTuple):
    n_tokens: int
    d_model: int
    n_values: int
    heads: int
    knn: int


# PKM value aggregation through the unified planned layer (PR 5): one MoE
# layer's worth of tokens selecting H*K of n_values value rows each — the
# expert_size-1 regime where the dense path materializes an (N, H*K, d)
# value gather that the GatherPlan-driven streamed kernels never build.
PKM = PkmBenchConfig(n_tokens=192, d_model=128, n_values=512, heads=2, knn=8)


def _pkm_setup(cfg: PkmBenchConfig, dtype=jnp.float32):
    key = jax.random.PRNGKey(0)
    ki, kw, kv = jax.random.split(key, 3)
    s = cfg.heads * cfg.knn
    idx = jax.random.randint(ki, (cfg.n_tokens, s), 0, cfg.n_values)
    w = jax.nn.relu(jax.random.normal(kw, (cfg.n_tokens, s), jnp.float32))
    values = (0.3 * jax.random.normal(
        kv, (cfg.n_values, cfg.d_model))).astype(dtype)
    return values, idx, w


def _pkm_agg(impl: str, cfg: PkmBenchConfig):
    """The PKM aggregation y[t] = sum_s w[t,s] * V[idx[t,s]] per chain rung —
    mirroring core/dispatch.weighted_value_sum exactly (plan built per call,
    as in production)."""
    def f(values, idx, w):
        if impl == "dense":
            return jnp.einsum("ns,nsd->nd", w.astype(values.dtype),
                              values[idx])
        plan = ops.make_gather_plan(idx, w, cfg.n_values)
        return ops.gathered_weighted_sum(
            values, plan, cfg.n_tokens,
            fuse_weights=(impl == "pallas_fused"))
    return f


def _bench_pkm(cfg: PkmBenchConfig, iters: int) -> dict:
    args = _pkm_setup(cfg)
    results = {}
    for impl in ("dense", "pallas", "pallas_fused"):
        f = _pkm_agg(impl, cfg)
        entry = {"fwd_us": round(_time(jax.jit(f), args, iters), 1)}
        probe = lambda v, i, w: f(v, i, w).astype(jnp.float32).sum()
        grad = jax.jit(jax.grad(probe, argnums=(0, 2)))
        entry["fwd_bwd_us"] = round(_time(grad, args, iters), 1)
        results[impl] = entry
    speedup = {
        k: round(results["dense"][f"{k}_us"]
                 / max(results["pallas_fused"][f"{k}_us"], 1e-9), 3)
        for k in ("fwd", "fwd_bwd")}
    plan = ops.make_gather_plan(args[1], args[2], cfg.n_values)
    return {"config": cfg._asdict(), "results": results,
            "pkm_speedup_vs_dense": speedup,
            "tiles": _gather_tile_report(cfg.d_model),
            "dma_descriptors": ops.plan_dma_stats(plan, cfg.n_values)}


class PkmLargeBenchConfig(NamedTuple):
    n_tokens: int
    d_model: int
    n_subkeys: int     # n_values = n_subkeys**2 (the config single-source)
    heads: int
    knn: int
    hot_values: int    # size of the co-selected hot set
    hot_frac: float    # fraction of selections landing on the hot set

    @property
    def n_values(self) -> int:
        return self.n_subkeys * self.n_subkeys


# Coalescing-scale PKM aggregation (PR 7): 65536 values, 256 tokens each
# selecting H*K = 64 rows (16384 selections), 90% of them on a 1024-row hot
# set. Dedup collapses the hot mass to <= 1024 DMA slots, so the plan issues
# ~2.6k descriptors for 16.4k selections — the gateable >= 4x batching win
# the flat per-selection plan could never show (1.003 at the pkm config).
PKM_LARGE = PkmLargeBenchConfig(n_tokens=256, d_model=128, n_subkeys=256,
                                heads=4, knn=16, hot_values=1024,
                                hot_frac=0.9)


def _pkm_large_setup(cfg: PkmLargeBenchConfig, dtype=jnp.float32):
    key = jax.random.PRNGKey(0)
    kh, kp, ks, kc, kw, kv = jax.random.split(key, 6)
    s = cfg.heads * cfg.knn
    shape = (cfg.n_tokens, s)
    hot = jax.random.choice(kh, cfg.n_values, (cfg.hot_values,),
                            replace=False)
    hot_idx = hot[jax.random.randint(ks, shape, 0, cfg.hot_values)]
    cold_idx = jax.random.randint(kc, shape, 0, cfg.n_values)
    idx = jnp.where(jax.random.uniform(kp, shape) < cfg.hot_frac,
                    hot_idx, cold_idx).astype(jnp.int32)
    w = jax.nn.relu(jax.random.normal(kw, shape, jnp.float32))
    values = (0.3 * jax.random.normal(
        kv, (cfg.n_values, cfg.d_model))).astype(dtype)
    return values, idx, w


def _pkm_large_agg(impl: str, cfg: PkmLargeBenchConfig):
    """Dense reference vs the dedup/sorted plan (the production
    weighted_value_sum lowering: compacted streamed gather + scatter-side
    weight indirection), plan built per call as in production."""
    def f(values, idx, w):
        if impl == "dense":
            return jnp.einsum("ns,nsd->nd", w.astype(values.dtype),
                              values[idx])
        plan = ops.make_dedup_gather_plan(idx, w, cfg.n_values)
        return ops.gathered_weighted_sum_dedup(values, plan, cfg.n_tokens)
    return f


def _dedup_gather_tile_report(d_model: int, itemsize: int = 4) -> dict:
    dec = autotune.dedup_gather_tiles(round_up(d_model, LANE), itemsize,
                                      budget=cvmm.VMEM_BUDGET)
    return {"gather": dec.tiles, "provenance": dec.provenance}


def _bench_pkm_large(cfg: PkmLargeBenchConfig, iters: int) -> dict:
    args = _pkm_large_setup(cfg)
    results = {}
    for impl in ("dense", "dedup"):
        f = _pkm_large_agg(impl, cfg)
        entry = {"fwd_us": round(_time(jax.jit(f), args, iters), 1)}
        probe = lambda v, i, w: f(v, i, w).astype(jnp.float32).sum()
        grad = jax.jit(jax.grad(probe, argnums=(0, 2)))
        entry["fwd_bwd_us"] = round(_time(grad, args, iters), 1)
        results[impl] = entry
    speedup = {
        k: round(results["dense"][f"{k}_us"]
                 / max(results["dedup"][f"{k}_us"], 1e-9), 3)
        for k in ("fwd", "fwd_bwd")}
    plan = ops.make_dedup_gather_plan(args[1], args[2], cfg.n_values)
    return {"config": {**cfg._asdict(), "n_values": cfg.n_values},
            "results": results,
            "pkm_speedup_vs_dense": speedup,
            "tiles": _dedup_gather_tile_report(cfg.d_model),
            "dma_descriptors": ops.plan_dma_stats(plan, cfg.n_values)}


def _setup(cfg: BenchConfig, dtype=jnp.float32):
    key = jax.random.PRNGKey(0)
    kx, ki, kg, k1, k2, k3 = jax.random.split(key, 6)
    xf = jax.random.normal(kx, (cfg.n_tokens, cfg.d_model),
                           jnp.float32).astype(dtype)
    idx = jax.random.randint(ki, (cfg.n_tokens, cfg.k), 0, cfg.n_experts)
    gates = jax.nn.softmax(
        jax.random.normal(kg, (cfg.n_tokens, cfg.k), jnp.float32), -1)
    w1 = (0.3 * jax.random.normal(
        k1, (cfg.n_experts, cfg.d_model, cfg.expert_size))).astype(dtype)
    w1g = (0.3 * jax.random.normal(
        k2, (cfg.n_experts, cfg.d_model, cfg.expert_size))).astype(dtype)
    w2 = (0.3 * jax.random.normal(
        k3, (cfg.n_experts, cfg.expert_size, cfg.d_model))).astype(dtype)
    return xf, idx, gates, w1, w1g, w2


def _mlp(impl: str, cfg: BenchConfig):
    """The sort-path expert MLP at a fixed routing, per impl — mirroring
    core/moe.py's dispatch exactly so the tracked fused-vs-unfused ratio
    compares against the REAL production unfused path (one shared plan via
    cvmm_planned, not a per-GEMM layout re-derivation)."""
    def f(xf, idx, gates, w1, w1g, w2):
        n = xf.shape[0]
        if impl.startswith("pallas"):
            plan = ops.make_moe_plan(idx, gates, n, cfg.n_experts)
            if impl == "pallas_fused":
                return ops.moe_mlp_fused(xf, plan, w1, w2,
                                         w1g if cfg.glu else None,
                                         activation="relu")
            interpret = ops._impl_interpret(impl)
            src = jnp.repeat(jnp.arange(n), cfg.k)[plan.perm]
            xs = xf[src]
            h = ops.cvmm_planned(xs, plan, w1, interpret=interpret)
            u = jax.nn.relu(h)
            if cfg.glu:
                u = u * ops.cvmm_planned(xs, plan, w1g, interpret=interpret)
            y = ops.cvmm_planned(u, plan, w2, interpret=interpret)
            y = y * gates.reshape(-1)[plan.perm][:, None].astype(y.dtype)
            return jnp.zeros_like(xf).at[src].add(y)
        e_flat = idx.reshape(-1)
        g_flat = gates.reshape(-1)
        tok = jnp.repeat(jnp.arange(n), cfg.k)
        perm = jnp.argsort(e_flat, stable=True)
        gs = jnp.bincount(e_flat, length=cfg.n_experts)
        xs = xf[tok[perm]]
        h = ops.cvmm(xs, gs, w1, impl=impl)
        u = jax.nn.relu(h)
        if cfg.glu:
            u = u * ops.cvmm(xs, gs, w1g, impl=impl)
        y = ops.cvmm(u, gs, w2, impl=impl)
        y = y * g_flat[perm][:, None].astype(y.dtype)
        return jnp.zeros_like(xf).at[tok[perm]].add(y)
    return f


def _time(fn, args, iters=ITERS):
    """us/call as the MINIMUM over ``iters`` individually synced calls.

    On a shared/loaded host a mean absorbs contention spikes straight into
    the CI-gated speedup ratios (observed swings > 50% run-to-run at low
    iters); the min estimates the uncontended cost of each program, which is
    the quantity the fused-vs-unfused comparison is about. Per-call sync
    overhead is negligible against these multi-ms interpret-mode kernels."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _est_bytes(impl: str, cfg: BenchConfig, itemsize: int = 4) -> dict:
    """Materialized-intermediate bytes for one fwd(+bwd), analytic model.

    Counts only buffers that round-trip through HBM *between* compute stages
    (the traffic fusion removes); weights/activations read in place are common
    to every impl and excluded. The streamed fused path never materializes the
    unsorted activations in any other layout at the XLA level — forward's only
    intermediates are the kernel outputs, and backward's tile-aligned gathers
    run inside the row-DMA gather kernel."""
    nk = cfg.n_tokens * cfg.k
    m_pad = round_up(nk, TM) + cfg.n_experts * TM
    d = round_up(cfg.d_model, LANE)
    g = round_up(cfg.expert_size, LANE)
    row = itemsize
    n_w1 = 2 if cfg.glu else 1
    if impl == "pallas_fused":
        # fwd: u (w1 out, act+GLU applied in-kernel) + y_pad (gate in-kernel)
        fwd = m_pad * g * row + m_pad * d * row
        # training fwd additionally writes h(/hg) in the same grid pass (no
        # recompute GEMMs in bwd); bwd is gather-free at the HBM level — dy
        # and x stream straight from the unsorted arrays, so only t0, the
        # elementwise dh(/dhg) and dx_pad round-trip through HBM.
        bwd = (n_w1 * m_pad * g + m_pad * g + m_pad * d) * row
    elif impl in ("pallas", "pallas_interpret"):
        # fwd: gathered xs + x_pad scatter + per-GEMM (pad in, out, unpad) +
        # act + GLU mult + gate mult as separate XLA passes
        fwd = (nk * d + m_pad * d                       # gather + pad
               + n_w1 * (m_pad * g + nk * g)            # w1(+w1g) out (+unpad)
               + nk * g                                 # act/GLU result
               + m_pad * g                              # u re-pad for w2
               + m_pad * d + nk * d + nk * d) * row     # w2 out, unpad, gate
        # bwd mirrors fwd: g_pad per GEMM + dx_pad/unpad + dw accumulators
        bwd = (3 * (m_pad * d + m_pad * g) + 2 * nk * d + 2 * nk * g) * row
    else:  # ragged
        fwd = (nk * d + n_w1 * nk * g + nk * g + nk * d + nk * d) * row
        bwd = (3 * (nk * d + nk * g)) * row
    return {"fwd": int(fwd), "fwd_bwd": int(fwd + bwd)}


def _dma_descriptors(cfg: BenchConfig, idx, gates) -> dict:
    """DMA descriptor counts of the plan at the routing that was timed."""
    plan = ops.make_moe_plan(idx, gates, cfg.n_tokens, cfg.n_experts)
    return ops.plan_dma_stats(plan, cfg.n_tokens)


def _bench_config(cfg: BenchConfig, iters: int, with_bwd: bool) -> dict:
    args = _setup(cfg)
    results = {}
    for impl in ("ragged", "pallas", "pallas_fused"):
        f = _mlp(impl, cfg)
        entry = {"fwd_us": round(_time(jax.jit(f), args, iters), 1),
                 "est_intermediate_bytes": _est_bytes(impl, cfg)}
        if with_bwd:
            probe = lambda *a: f(*a).astype(jnp.float32).sum()
            grad = jax.jit(jax.grad(probe, argnums=(0, 2, 3, 4, 5)))
            entry["fwd_bwd_us"] = round(_time(grad, args, iters), 1)
            # Backward-only: time the vjp cotangent pull directly (the fwd
            # runs once, outside the timed loop). Subtracting fwd_us from
            # fwd_bwd_us instead would difference two independently noisy
            # timings of DIFFERENT jitted programs (the grad's forward also
            # writes save_preact outputs) — too flaky to CI-gate.
            idxv = args[1]
            _, vjp = jax.vjp(
                lambda xf, gates, w1, w1g, w2:
                    probe(xf, idxv, gates, w1, w1g, w2),
                *(args[i] for i in (0, 2, 3, 4, 5)))
            bwd_fn = jax.jit(lambda ct: vjp(ct))
            entry["bwd_us"] = round(
                _time(bwd_fn, (jnp.ones((), jnp.float32),), iters), 1)
        results[impl] = entry
    speedup = {"fwd": round(results["pallas"]["fwd_us"]
                            / max(results["pallas_fused"]["fwd_us"], 1e-9), 3)}
    if with_bwd:
        speedup["fwd_bwd"] = round(
            results["pallas"]["fwd_bwd_us"]
            / max(results["pallas_fused"]["fwd_bwd_us"], 1e-9), 3)
        # backward-only: the streamed gather-free dW/dX path in isolation
        speedup["bwd"] = round(
            results["pallas"]["bwd_us"]
            / max(results["pallas_fused"]["bwd_us"], 1e-9), 3)
    return {"config": cfg._asdict(), "results": results,
            "fused_speedup_vs_pallas": speedup,
            "tiles": _moe_tile_report(cfg),
            "dma_descriptors": _dma_descriptors(cfg, args[1], args[2])}


def run(out_path: str = "BENCH_cvmm.json", iters: int = ITERS):
    # The CI-gated speedup ratios come from the base config, whose timed
    # programs are all ms-scale: floor its sample count so the min-of-N
    # estimator reliably sees an uncontended call on a shared host (~1s of
    # extra wall clock total, vs compile time in the tens of seconds).
    base = _bench_config(BASE, max(iters, 15), with_bwd=True)
    large_cfg = _large_n_config()
    # past the old residency boundary: fwd-only + few iters (interpret-mode
    # calls here are ~100x the base config's work per call)
    large = _bench_config(large_cfg, min(iters, 2), with_bwd=False)
    # PKM aggregation through the unified planned layer (PR 5). On CPU the
    # interpret-mode DMA pipeline is serialized python-traced copies while
    # the dense reference is one highly-tuned XLA gather+einsum, so the
    # ratio reads ~0.1 (fwd) / ~0.4 (fwd+bwd) here — TPU is where the
    # streamed gather pays. CI gates it as a regression TRIPWIRE (a planned
    # path that started doing dense-path work on top of the kernels would
    # crater the ratio), not a speedup claim.
    pkm = _bench_pkm(PKM, max(iters, 10))
    # Coalescing-scale PKM aggregation (PR 7): the gated signal here is the
    # dedup plan's batching_factor (>= 4.0), a pure plan property — stable
    # regardless of host load — so few iters suffice for the timings.
    pkm_large = _bench_pkm_large(PKM_LARGE, min(iters, 2))
    payload = {
        "config": {**base["config"], "iters": iters,
                   "backend": jax.default_backend(),
                   "note": "pallas impls run in interpret mode off-TPU"},
        "results": base["results"],
        "fused_speedup_vs_pallas": base["fused_speedup_vs_pallas"],
        "tiles": base["tiles"],
        "tune": _tune_report(),
        "dma_descriptors": base["dma_descriptors"],
        "pkm_speedup_vs_dense": pkm["pkm_speedup_vs_dense"],
        "pkm": {**pkm,
                "note": "value aggregation via GatherPlan + streamed gather "
                        "kernels vs the dense (N, S, d) take+einsum; "
                        "interpret-mode ratios are tripwires, see above"},
        "pkm_large": {**pkm_large,
                      "note": "65536-value duplicate-heavy aggregation via "
                              "the dedup/sorted plan (compacted streamed "
                              "gather + scatter-side weight indirection); "
                              "dma_descriptors.batching_factor is the "
                              "CI-gated coalescing signal (>= 4.0), timings "
                              "are interpret-mode tripwires"},
        "large_n": {**large,
                    "note": "token count past the retired whole-x VMEM "
                            "boundary; streamed row-DMA gather territory"},
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = [f"cvmm/{impl}_fwd,{r['fwd_us']},"
            f"est_bytes={r['est_intermediate_bytes']['fwd']}"
            for impl, r in base["results"].items()]
    rows += [f"cvmm/{impl}_fwd_bwd,{r['fwd_bwd_us']},"
             f"est_bytes={r['est_intermediate_bytes']['fwd_bwd']};"
             f"bwd_us={r['bwd_us']}"
             for impl, r in base["results"].items()]
    rows += [f"cvmm/large_n{large_cfg.n_tokens}/{impl}_fwd,{r['fwd_us']},"
             f"est_bytes={r['est_intermediate_bytes']['fwd']}"
             for impl, r in large["results"].items()]
    rows += [f"cvmm/pkm_agg/{impl}_fwd,{r['fwd_us']},"
             f"fwd_bwd_us={r['fwd_bwd_us']}"
             for impl, r in pkm["results"].items()]
    rows += [f"cvmm/pkm_large/{impl}_fwd,{r['fwd_us']},"
             f"fwd_bwd_us={r['fwd_bwd_us']}"
             for impl, r in pkm_large["results"].items()]
    dd = pkm_large["dma_descriptors"]
    rows.append(
        f"cvmm/pkm_large/dma,{dd['run_batched']},"
        f"batching_factor={dd['batching_factor']};"
        f"per_row={dd['per_row']};unique_rows={dd['unique_rows']};"
        f"speedup_vs_dense={pkm_large['pkm_speedup_vs_dense']['fwd']}x")
    rows.append(
        f"# wrote {out_path}; fused/unfused speedups fwd+bwd "
        f"{payload['fused_speedup_vs_pallas']['fwd_bwd']}x / bwd-only "
        f"{payload['fused_speedup_vs_pallas']['bwd']}x; DMA batching "
        f"{payload['dma_descriptors']['batching_factor']}x (base) / "
        f"{large['dma_descriptors']['batching_factor']}x (large-N); large-N "
        f"(n={large_cfg.n_tokens}) fwd speedup "
        f"{large['fused_speedup_vs_pallas']['fwd']}x; pkm-agg vs dense "
        f"{payload['pkm_speedup_vs_dense']['fwd']}x fwd / "
        f"{payload['pkm_speedup_vs_dense']['fwd_bwd']}x fwd+bwd "
        f"(interpret-mode tripwire); pkm-large "
        f"({PKM_LARGE.n_values} values) dedup batching "
        f"{dd['batching_factor']}x over {dd['run_batched']} descriptors")
    tune = payload["tune"]
    fused = payload["tiles"]["fused"] or {}
    rows.append(
        f"# tiles {fused.get('provenance', 'none')}: "
        f"w1_tn={fused.get('w1_tn')} w2_tn={fused.get('w2_tn')} "
        f"dw_tb={fused.get('dw_tb')}; tune enabled={tune['enabled']} "
        f"microbench_calls={tune['microbench_calls']} "
        f"cache_hits={tune['cache_hits']}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_cvmm.json")
    ap.add_argument("--iters", type=int, default=ITERS)
    args = ap.parse_args()
    for row in run(args.out, args.iters):
        print(row)


if __name__ == "__main__":
    main()
