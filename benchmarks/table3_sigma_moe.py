"""Paper Table 3 (MAIN RESULT): parameter-matched sigma-MoE vs dense.

Paper claim: sigma-MoE matches/beats the dense baseline at ~25% of the FFN FLOPs.
Reduced-scale: dense d_ff=256 vs sigma-MoE N_E=8, G=32 (d_ff=256), K=2 -> 25%
active. Both dispatch paths are timed (sort == the CVMM kernel path).
"""
import dataclasses

from repro.configs import moe_ffn
from repro.configs.base import FFNConfig

from .common import csv_row, tiny_lm, train_variant


def run(steps: int = 150):
    rows = []
    dense = FFNConfig(kind="dense", d_ff=256, activation="relu")
    smoe = moe_ffn(8, 32, 2, reg_gamma=1e-3, reg_kind="entropy", dispatch="sort")
    for name, ffn in [("dense", dense), ("sigma_moe_k2of8", smoe),
                      ("sigma_moe_einsum", dataclasses.replace(smoe,
                                                               dispatch="einsum"))]:
        r = train_variant(f"table3/{name}", tiny_lm(ffn), steps=steps)
        rows.append(csv_row(
            r["name"], r["us_per_step"],
            f"final_loss={r['final_loss']:.4f};params={r['params']};"
            f"ffn_flops={r['ffn_flops_pct']:.1f}%"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
