"""Paper Table 4/10: MoE design ablations -- selector activation, init,
regularization, expert dropout, (G, K) trade-off, Switch/S-BASE baselines."""
import dataclasses

from repro.configs import moe_ffn

from .common import csv_row, tiny_lm, train_variant

NE, G, K = 8, 32, 2


def variants():
    base = moe_ffn(NE, G, K, reg_gamma=1e-3, reg_kind="entropy", dispatch="sort",
                   expert_dropout=0.05)
    yield "sigma_moe", base
    yield "standard_dropout", dataclasses.replace(base, expert_dropout=0.0)
    yield "softmax_after_topk", dataclasses.replace(
        base, selector_activation="softmax", renormalize=False)
    yield "softmax_renorm", dataclasses.replace(
        base, selector_activation="softmax", renormalize=True)
    yield "standard_init", dataclasses.replace(base, sigma_moe_init=False)
    yield "no_reg", dataclasses.replace(base, reg_gamma=0.0, expert_dropout=0.0)
    yield "k4_g16", moe_ffn(16, 16, 4, reg_gamma=1e-3, dispatch="sort")
    yield "k1_g64", moe_ffn(4, 64, 1, reg_gamma=1e-3, dispatch="sort")
    yield "switch_k1_g64", dataclasses.replace(
        moe_ffn(4, 64, 1, reg_kind="switch", reg_gamma=1e-2, dispatch="einsum"),
        kind="switch", selector_activation="softmax")
    yield "sbase_k2_g32", dataclasses.replace(
        moe_ffn(NE, G, K, reg_gamma=1e-3, dispatch="sort"), kind="sbase")
    yield "noisy_topk", dataclasses.replace(
        moe_ffn(NE, G, K, reg_kind="cv", reg_gamma=1e-2, dispatch="sort"),
        kind="noisy_topk", selector_activation="softmax", renormalize=True)


def run(steps: int = 100):
    rows = []
    for name, ffn in variants():
        r = train_variant(f"table4/{name}", tiny_lm(ffn), steps=steps)
        rows.append(csv_row(r["name"], r["us_per_step"],
                            f"final_loss={r['final_loss']:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
