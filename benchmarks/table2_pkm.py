"""Paper Table 2/6: PKM softmax vs ReLU vs dense (parameter-matched).

Paper claim: ReLU (non-competitive) PKM clearly beats softmax PKM; both trail dense.

Since PR 5 the derived column also reports which rung of the unified
execution layer's chain each PKM variant lowers to (``path=``, via
``core.dispatch.value_sum_path``): on TPU this reads ``pallas_fused`` (value
aggregation through GatherPlan + the streamed gather kernels); on the CPU
bench host the auto default is the einsum rung. Dense FFNs report
``path=matmul`` (no selection, nothing to plan).
"""
from repro.configs.base import FFNConfig
from repro.core.dispatch import value_sum_path

from .common import csv_row, tiny_lm, train_variant

D_MODEL = 64


def run(steps: int = 120):
    # dense d_ff=256 -> params 2*64*256 = 32k. PKM: values ns^2*64 + keys; ns=18
    # gives 324 values ~ 20.7k + keys 2*2*18*32 = 2.3k; parameter-matched-ish.
    rows = []
    variants = [
        ("dense", FFNConfig(kind="dense", d_ff=256, activation="relu")),
        ("pkm_softmax", FFNConfig(kind="pkm", n_subkeys=18, pkm_heads=2,
                                  pkm_knn=8, activation="softmax")),
        ("pkm_relu", FFNConfig(kind="pkm", n_subkeys=18, pkm_heads=2,
                               pkm_knn=8, activation="relu")),
        ("pkm_relu_init", FFNConfig(kind="pkm", n_subkeys=18, pkm_heads=2,
                                    pkm_knn=8, activation="relu",
                                    sigma_moe_init=True)),
    ]
    for name, ffn in variants:
        r = train_variant(f"table2/{name}", tiny_lm(ffn, d_model=D_MODEL),
                          steps=steps)
        path = (value_sum_path(ffn, D_MODEL) if ffn.kind == "pkm"
                else "matmul")
        rows.append(csv_row(
            r["name"], r["us_per_step"],
            f"final_loss={r['final_loss']:.4f};params={r['params']};"
            f"path={path}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
