"""Paper Table 2/6: PKM softmax vs ReLU vs dense (parameter-matched).

Paper claim: ReLU (non-competitive) PKM clearly beats softmax PKM; both trail dense.
"""
from repro.configs.base import FFNConfig

from .common import csv_row, tiny_lm, train_variant


def run(steps: int = 120):
    # dense d_ff=256 -> params 2*64*256 = 32k. PKM: values ns^2*64 + keys; ns=18
    # gives 324 values ~ 20.7k + keys 2*2*18*32 = 2.3k; parameter-matched-ish.
    rows = []
    variants = [
        ("dense", FFNConfig(kind="dense", d_ff=256, activation="relu")),
        ("pkm_softmax", FFNConfig(kind="pkm", n_subkeys=18, pkm_heads=2,
                                  pkm_knn=8, activation="softmax")),
        ("pkm_relu", FFNConfig(kind="pkm", n_subkeys=18, pkm_heads=2,
                               pkm_knn=8, activation="relu")),
        ("pkm_relu_init", FFNConfig(kind="pkm", n_subkeys=18, pkm_heads=2,
                                    pkm_knn=8, activation="relu",
                                    sigma_moe_init=True)),
    ]
    for name, ffn in variants:
        r = train_variant(f"table2/{name}", tiny_lm(ffn), steps=steps)
        rows.append(csv_row(r["name"], r["us_per_step"],
                            f"final_loss={r['final_loss']:.4f};params={r['params']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
