"""Shared benchmark harness: tiny-scale training comparisons + layer timers.

Full-scale perplexity reproduction needs 100k GPU-steps; this container is a single
CPU core. The benchmarks therefore (a) reproduce each paper table's COMPARISON at
reduced scale (same architectures, same parameter-matching discipline, same
ablations, synthetic data, few hundred steps) and (b) measure wall-clock/bytes of
the layer implementations. Table-level CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig, FFNConfig, ModelConfig, OptimizerConfig
from repro.data import DataIterator, make_dataset
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step

VOCAB = 256


def tiny_lm(ffn: FFNConfig, d_model: int = 64, n_layers: int = 2,
            vocab: int = VOCAB) -> ModelConfig:
    return ModelConfig(
        name="bench", family="dense", n_layers=n_layers, d_model=d_model,
        vocab_size=vocab, norm="layernorm", pos_encoding="rope",
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                                  kv_chunk=64),
        ffn=ffn, tie_embeddings=True)


def train_variant(name: str, cfg: ModelConfig, *, steps: int = 120,
                  batch: int = 8, seq: int = 64, lr: float = 3e-3,
                  seed: int = 0) -> Dict[str, float]:
    """Train on the deterministic synthetic stream; return loss + timing stats."""
    model = build_model(cfg)
    opt = OptimizerConfig(lr=lr, total_steps=steps, grad_clip=0.25)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(seed), opt)
    it = DataIterator(make_dataset("synthetic", cfg.vocab_size), batch, seq + 1,
                      seed=seed)
    rng = jax.random.PRNGKey(seed + 1)
    losses = []
    t0 = None
    for s in range(steps):
        b = {"tokens": jnp.asarray(it.next()["tokens"])}
        state, m = step_fn(state, b, rng)
        losses.append(float(m["loss"]))
        if s == 4:                       # skip compile in timing
            t0 = time.perf_counter()
    dt = (time.perf_counter() - t0) / max(steps - 5, 1)
    tail = float(np.mean(losses[-10:]))
    pc = cfg.param_counts()
    _, active = cfg.ffn_params()
    total_ffn, _ = cfg.ffn_params()
    return {
        "name": name, "final_loss": tail, "first_loss": losses[0],
        "us_per_step": dt * 1e6, "params": pc["total"],
        "ffn_flops_pct": 100.0 * active / max(total_ffn, 1),
    }


def time_layer(apply_fn, params, x, *, iters: int = 20) -> float:
    """us per fwd+bwd call of a single layer."""
    f = jax.jit(jax.grad(lambda p, x: apply_fn(p, x)[0].astype(jnp.float32).sum()))
    g = f(params, x)
    jax.block_until_ready(g)
    t0 = time.perf_counter()
    for _ in range(iters):
        g = f(params, x)
    jax.block_until_ready(g)
    return (time.perf_counter() - t0) / iters * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
