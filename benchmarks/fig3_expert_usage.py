"""Paper Fig. 3/7: expert utilization / collapse analysis.

Trains sigma-MoE and the 'softmax (renorm.)' ablation, then reports per-expert
selection-weight share + usage entropy. Paper claim: softmax+renorm collapses,
sigma-MoE stays balanced without Sinkhorn.

Since PR 5 the same probe also covers PKM: the uniform ``collect_stats`` aux
contract (core/dispatch.selection_usage) yields the value-usage histogram, so
memory-slot collapse is reported on the same axes as expert collapse — the
framework's selection rules are directly comparable."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import moe_ffn
from repro.configs.base import FFNConfig, OptimizerConfig
from repro.core.moe import _route
from repro.core.pkm import apply_pkm
from repro.core.regularizers import usage_stats
from repro.data import DataIterator, make_dataset
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step

from .common import csv_row, tiny_lm

NE, G, K = 8, 32, 2
PKM_NS = 12                              # 144 values, tiny-bench scale


def _train(ffn, steps):
    cfg = tiny_lm(ffn)
    model = build_model(cfg)
    opt = OptimizerConfig(lr=3e-3, total_steps=steps)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    it = DataIterator(make_dataset("synthetic", cfg.vocab_size), 8, 65, seed=0)
    for _ in range(steps):
        state, _ = step_fn(state, {"tokens": jnp.asarray(it.next()["tokens"])},
                           jax.random.PRNGKey(1))
    # layer-0 FFN params + a validation activation batch for probing
    params = state["params"]
    toks = jnp.asarray(it.next()["tokens"])[:, :-1]
    x = params["emb"].astype(model.dtype)[toks].reshape(-1, cfg.d_model)
    blk = jax.tree_util.tree_map(lambda a: a[0],
                                 params["stack"]["segments"][0]["e0"])
    return blk["ffn"], x


def _report(name, st, n_items):
    share = np.sort(np.asarray(st["weight"]))[::-1]
    share = share / max(share.sum(), 1e-9)
    return csv_row(f"fig3/{name}", 0.0,
                   f"usage_entropy={float(st['usage_entropy']):.3f};"
                   f"top1_share={share[0]:.2f};max_entropy={np.log(n_items):.3f}")


def _train_and_probe(name, ffn, steps=120):
    fp, x = _train(ffn, steps)
    info = _route(fp, x, ffn, None, False, NE)
    return _report(name, usage_stats(info, NE), NE)


def _train_and_probe_pkm(name, ffn, steps=120):
    fp, x = _train(ffn, steps)
    _, aux = apply_pkm(fp, x, ffn, collect_stats=True)
    return _report(name, aux["usage"], ffn.n_values)


def run(steps: int = 120):
    base = moe_ffn(NE, G, K, reg_gamma=1e-3, reg_kind="entropy", dispatch="sort",
                   expert_dropout=0.05)
    bad = dataclasses.replace(base, selector_activation="softmax",
                              renormalize=True, reg_gamma=0.0, expert_dropout=0.0)
    pkm = FFNConfig(kind="pkm", n_subkeys=PKM_NS, pkm_heads=2, pkm_knn=8,
                    activation="relu")
    return [_train_and_probe("sigma_moe", base, steps),
            _train_and_probe("softmax_renorm_noreg", bad, steps),
            _train_and_probe_pkm("pkm_value_usage", pkm, steps)]


if __name__ == "__main__":
    print("\n".join(run()))
