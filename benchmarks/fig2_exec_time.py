"""Paper Fig. 2/8-11: execution time + memory of a single MLP vs MoE layer as
d_model grows (K=4, G=128, d_ff=4*d_model, N_E=d_ff/G), fwd+bwd.

The paper measures its Triton kernel on an RTX 3090; here we measure the JAX layer
(CVMM sort path on CPU + the einsum path) -- the comparison of interest is the
RELATIVE cost MoE/dense and its scaling in d_model, plus parameter bytes touched.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs import moe_ffn
from repro.configs.base import FFNConfig
from repro.core import apply_dense, apply_moe, init_dense, init_moe
from repro.kernels import ops as kops
from repro.kernels.cvmm import TM, legacy_whole_x_rows

from .common import csv_row, time_layer

TOKENS = 2048          # |B| scaled down from the paper's 32768 for CPU

# The fused-CVMM row runs the pallas kernels, which off-TPU execute in
# interpret mode — meaningful but slow, so it is measured at the smallest
# d_model only (always on TPU; opt in everywhere with REPRO_BENCH_FUSED=1).
_FUSED_ALWAYS = os.environ.get("REPRO_BENCH_FUSED", "") not in ("", "0")


def run():
    rows = []
    for d_model in (128, 256, 512):
        d_ff = 4 * d_model
        g = 128
        ne = d_ff // g
        k = min(4, ne)
        x = jax.random.normal(jax.random.PRNGKey(0), (TOKENS, d_model),
                              jnp.float32)

        dcfg = FFNConfig(kind="dense", d_ff=d_ff, activation="relu")
        dp = init_dense(jax.random.PRNGKey(1), d_model, dcfg, 1)
        us_d = time_layer(lambda p, x: apply_dense(p, x, dcfg), dp, x, iters=5)
        bytes_d = 2 * d_model * d_ff * 4
        rows.append(csv_row(f"fig2/dense_d{d_model}", us_d,
                            f"param_bytes={bytes_d}"))

        mcfg = moe_ffn(ne, g, k, dispatch="sort")
        mp = init_moe(jax.random.PRNGKey(1), d_model, mcfg, 1)
        us_m = time_layer(lambda p, x: apply_moe(p, x, mcfg), mp, x, iters=5)
        active_bytes = int(bytes_d * k / ne)
        rows.append(csv_row(
            f"fig2/moe_sort_d{d_model}", us_m,
            f"active_param_bytes={active_bytes};ratio_vs_dense={us_m/us_d:.2f}"))

        ecfg = dataclasses.replace(mcfg, dispatch="einsum")
        us_e = time_layer(lambda p, x: apply_moe(p, x, ecfg), mp, x, iters=5)
        rows.append(csv_row(
            f"fig2/moe_einsum_d{d_model}", us_e,
            f"active_param_bytes={active_bytes};ratio_vs_dense={us_e/us_d:.2f}"))

        if jax.default_backend() == "tpu" or _FUSED_ALWAYS or d_model == 128:
            kops.set_default_impl("pallas_fused")
            try:
                us_f = time_layer(lambda p, x: apply_moe(p, x, mcfg), mp, x,
                                  iters=3)
            finally:
                kops.set_default_impl(None)
            # the tiles this config's fused kernels launched with, plus the
            # tuner provenance (heuristic vs tuned) — so fig2 rows are
            # attributable to a tile decision when comparing across machines
            kplan = kops.plan_sort_kernels("pallas_fused", d_model, g,
                                           mcfg.activation, x.dtype,
                                           glu=mcfg.glu_experts)
            tiles = ("none" if kplan.fused is None else
                     f"{kplan.fused.provenance}:w1_tn={kplan.fused.w1_tn}:"
                     f"w2_tn={kplan.fused.w2_tn}:dw_tb={kplan.fused.dw_tb}")
            rows.append(csv_row(
                f"fig2/moe_sort_fused_d{d_model}", us_f,
                f"active_param_bytes={active_bytes};"
                f"ratio_vs_sort={us_f/us_m:.2f};tiles={tiles}"))

    # The streamed-gather regime: a token count PAST the retired whole-x VMEM
    # residency boundary, where the pre-streaming gate rejected the fused path
    # and silently fell back to the unfused kernels. One row, d_model=128,
    # K=1/no-GLU to keep the interpret-mode fwd+bwd tolerable on CPU.
    d_model = 128
    n_large = legacy_whole_x_rows(k_pad=d_model, bytes_per_el=4,
                                  n_weights=1, n_out=2) + TM
    lcfg = moe_ffn(4, 128, 1, dispatch="sort")
    lp = init_moe(jax.random.PRNGKey(1), d_model, lcfg, 1)
    xl = jax.random.normal(jax.random.PRNGKey(2), (n_large, d_model),
                           jnp.float32)
    # pin the baseline to the UNFUSED pallas path: on TPU the default impl is
    # pallas_fused, which would make ratio_vs_sort compare fused to itself
    kops.set_default_impl("pallas")
    try:
        us_u = time_layer(lambda p, x: apply_moe(p, x, lcfg), lp, xl, iters=2)
    finally:
        kops.set_default_impl(None)
    kops.set_default_impl("pallas_fused")
    try:
        us_s = time_layer(lambda p, x: apply_moe(p, x, lcfg), lp, xl, iters=2)
    finally:
        kops.set_default_impl(None)
    # time_layer measures fwd+bwd, so this row tracks the gather-free streamed
    # backward too; also report run-batched DMA descriptor counts vs the
    # retired one-copy-per-row scheme. The timed run's routing lives inside
    # apply_moe, so the counts come from a same-shape PROBE plan (uniform
    # random K=1 routing) — representative of the token/expert geometry, not
    # the exact timed selection.
    probe_idx = jax.random.randint(jax.random.PRNGKey(3), (n_large, 1), 0,
                                   lcfg.n_experts)
    plan = kops.make_moe_plan(probe_idx, jnp.ones((n_large, 1)), n_large,
                              lcfg.n_experts)
    dma = kops.plan_dma_stats(plan, n_large)
    rows.append(csv_row(
        f"fig2/moe_sort_fused_stream_n{n_large}", us_s,
        f"past_whole_x_budget=1;fwd_bwd=1;ratio_vs_sort={us_s/us_u:.2f};"
        f"probe_dma_descriptors={dma['run_batched']};"
        f"probe_dma_per_row={dma['per_row']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
