"""Paper Table 1: Top-K activation vs dense, K sweep. Reduced-scale reproduction:
the paper finds top-K preserves (even slightly improves) loss down to K ~ d_ff/16."""
from repro.configs.base import FFNConfig

from .common import csv_row, tiny_lm, train_variant

D_FF = 256


def run(steps: int = 120):
    rows = []
    variants = [("dense", FFNConfig(kind="dense", d_ff=D_FF, activation="relu"))]
    for k in (16, 32, 64, 128):
        variants.append((f"topk_k{k}", FFNConfig(kind="topk", d_ff=D_FF,
                                                 topk_k=k, activation="relu")))
    for name, ffn in variants:
        r = train_variant(f"table1/{name}", tiny_lm(ffn), steps=steps)
        rows.append(csv_row(r["name"], r["us_per_step"],
                            f"final_loss={r['final_loss']:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
