"""Benchmark aggregator -- one module per paper table/figure, plus the CVMM
hot-path micro-benchmark (bench_cvmm -> BENCH_cvmm.json). The cvmm module's
``pkm_large`` section (64k+ value PKM aggregation through the deduplicated
coalescing gather) rides the --quick subset and carries the CI-gated
``dma_descriptors.batching_factor`` coalescing signal.

    PYTHONPATH=src python -m benchmarks.run [--steps N] [--only tableX]
    PYTHONPATH=src python -m benchmarks.run --quick    # smoke: cvmm + fig2
    PYTHONPATH=src python -m benchmarks.run --quick --tune  # pre-warm tile cache

``--tune`` turns on the kernel autotuner (kernels/autotune.py) for this run:
tile choices come from the persistent on-disk cache, micro-benchmarking any
missing (kernel, shape, dtype, backend) keys once and storing the winners, so
a subsequent run — bench or training — is a pure cache hit. Without it the
tuner stays in zero-cost heuristic mode (the CI default).

Prints ``name,us_per_call,derived`` CSV rows per benchmark.
"""
import argparse
import sys
import time

QUICK = ("cvmm", "fig2", "serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="fast smoke subset (%s) with reduced iters" %
                         ",".join(QUICK))
    ap.add_argument("--tune", action="store_true",
                    help="enable the kernel autotuner: micro-bench uncached "
                         "tile candidates and persist winners to the on-disk "
                         "cache (pre-warms it for later runs)")
    args = ap.parse_args()

    if args.tune:
        from repro.kernels import autotune
        autotune.enable(True)
        print(f"# autotune on: cache={autotune.cache_path()}", flush=True)

    from . import (bench_cvmm, bench_serve, fig1_active_channels,
                   fig2_exec_time, fig3_expert_usage, table1_topk,
                   table2_pkm, table3_sigma_moe, table4_ablations)
    mods = {
        "cvmm": lambda: bench_cvmm.run(iters=3 if args.quick else 10),
        "serve": lambda: bench_serve.run(quick=args.quick),
        "table1": lambda: table1_topk.run(args.steps),
        "table2": lambda: table2_pkm.run(args.steps),
        "table3": lambda: table3_sigma_moe.run(max(args.steps, 150)),
        "table4": lambda: table4_ablations.run(max(args.steps - 20, 60)),
        "fig1": lambda: fig1_active_channels.run(args.steps),
        "fig2": lambda: fig2_exec_time.run(),
        "fig3": lambda: fig3_expert_usage.run(args.steps),
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in mods.items():
        if args.only and name != args.only:
            continue
        if args.quick and name not in QUICK:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # report and continue
            failures += 1
            print(f"{name},nan,ERROR={type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if args.tune:
        from repro.kernels import autotune
        print(f"# autotune stats: {autotune.STATS}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
