"""Paper Fig. 1/4/5: number of active (ReLU>0) channels in u of a trained dense
model -- the sparsity observation motivating the whole paper."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FFNConfig, OptimizerConfig
from repro.data import DataIterator, make_dataset
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step

from .common import csv_row, tiny_lm


def run(steps: int = 150):
    ffn = FFNConfig(kind="dense", d_ff=256, activation="relu")
    cfg = tiny_lm(ffn)
    model = build_model(cfg)
    opt = OptimizerConfig(lr=3e-3, total_steps=steps)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    it = DataIterator(make_dataset("synthetic", cfg.vocab_size), 8, 65, seed=0)
    rng = jax.random.PRNGKey(1)
    for _ in range(steps):
        state, _ = step_fn(state, {"tokens": jnp.asarray(it.next()["tokens"])},
                           rng)

    # probe u = relu(W1 x) per layer on held-out batch
    params = state["params"]
    toks = jnp.asarray(it.next()["tokens"])[:, :-1]
    h, _, _ = model.forward(params, toks)

    # recompute per-layer activations by stepping through the stack manually
    from repro.models.layers import apply_norm
    x = params["emb"].astype(model.dtype)[toks]
    seg = params["stack"]["segments"][0]
    rows = []
    for li in range(cfg.n_layers):
        blk = jax.tree_util.tree_map(lambda a: a[li], seg["e0"])
        from repro.models.attention import apply_attention
        hh = apply_norm(blk["norm1"], x, cfg)
        y, _ = apply_attention(blk["attn"], hh, cfg,
                               positions=jnp.arange(x.shape[1]))
        x = x + y
        hh = apply_norm(blk["norm2"], x, cfg)
        u = jax.nn.relu(jnp.einsum("bsd,df->bsf", hh,
                                   blk["ffn"]["w1"].astype(hh.dtype)))
        active = float((u > 0).mean()) * ffn.d_ff
        rows.append(csv_row(f"fig1/layer{li}", 0.0,
                            f"active_channels={active:.1f}/{ffn.d_ff}"))
        y2 = jnp.einsum("bsf,fd->bsd", u, blk["ffn"]["w2"].astype(hh.dtype))
        x = x + y2
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
