"""Continuous-batching serve benchmark -> ``BENCH_serve.json``.

Drives the real engine (repro.serving) on the reduced granite MoE config
(sort dispatch, GLU experts, k=2 — the decode-plan provider's target) and
records the serving signals CI gates on:

* ``plan_rebuilds`` — decode-plan skeleton rebuilds across a >= 32-step
  steady-state window. The engine's capture-size menu + the shape-keyed
  skeleton cache mean a warmed engine NEVER rebuilds a plan: the gate pins
  this to 0, so any change that sneaks per-step plan construction (or a
  retrace) back into the decode loop fails CI.
* ``tok_s`` and ``decode_step_us.p50/p99`` — aggregate throughput and
  per-step decode latency (burst_steps=1, so each sample is one real
  jitted step including its single host readback).
* ``prefill_ms`` — mean per-chunk prefill latency (the disaggregation
  quantum: decode stalls at most this long per scheduling iteration).
* ``dma_descriptors`` — the decode skeleton's dedup token-gather chunk
  histogram / unique-row counts and the assembled plan's run-batched
  descriptor stats, both verified against the plan-invariant oracle in the
  same call (``verify=True``).

On CPU the pallas kernels run in interpret mode, so absolute tok/s are not
TPU numbers; the structural signals (rebuilds, descriptor stats) are
load-independent. Run:  PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

STEADY_STEPS = 32


def _requests(cfg, n, prompt_len, max_new, rng):
    from repro.serving import Request
    return [Request(rid=f"r{i}",
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=prompt_len).tolist(),
                    max_new=max_new, eos=-1)
            for i in range(n)]


def _decode_plan_report(plan_cache):
    """DMA/layout telemetry from the cached skeletons (verified against the
    plan-invariant oracle), plus an assembled-plan invalidation demo."""
    import jax.numpy as jnp
    from repro.kernels import ops

    skels = [p for p in plan_cache._skeletons.values() if p is not None]
    if not skels:
        return {"note": "no decode plans built (provider never served)"}
    skel = max(skels, key=lambda p: p.n_tokens)
    gather = ops.plan_dma_stats(skel.gather, skel.n_tokens, verify=True)

    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, skel.n_experts,
                                   size=(skel.n_tokens, skel.k)), jnp.int32)
    gates = jnp.asarray(rng.random((skel.n_tokens, skel.k)), jnp.float32)
    full = plan_cache.assembled(skel, idx, gates)
    assembled = ops.plan_dma_stats(full, skel.n_tokens, verify=True)
    # stable routing -> cache hit; changed routing -> new assembly
    before = plan_cache.assembles
    plan_cache.assembled(skel, idx, gates)
    stable_hit = plan_cache.assembles == before
    idx2 = (idx + 1) % skel.n_experts
    plan_cache.assembled(skel, idx2, gates)
    routing_invalidates = plan_cache.assembles == before + 1
    return {
        "shape": {"n_tokens": skel.n_tokens, "k": skel.k,
                  "n_experts": skel.n_experts, "cap": skel.cap,
                  "m_pad": skel.m_pad, "w1_tn": skel.w1_tn,
                  "w2_tn": skel.w2_tn, "provenance": skel.provenance},
        "dedup_gather": gather,
        "assembled": assembled,
        "assembled_cache": {"stable_routing_hit": bool(stable_hit),
                            "routing_change_invalidates":
                                bool(routing_invalidates)},
    }


def run(out_path: str = "BENCH_serve.json", quick: bool = True):
    import jax
    from repro.configs.archs import reduced
    from repro.models.lm import LM
    from repro.serving import Engine

    cfg = reduced("granite-moe-3b-a800m")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    max_batch, prompt_len, max_new = 4, 6, 20
    burst = 8
    eng = Engine(lm, params, max_batch=max_batch, max_len=96, page_size=8,
                 burst_steps=burst, prefill_chunk=8,
                 prefill_chunks_per_step=2)
    try:
        # ---- warmup: compile every (capture, steps) the workload visits and
        # populate the plan-skeleton cache. Identical request pattern to the
        # steady-state window, so the window itself is pure cache hits.
        eng.run(_requests(cfg, max_batch, prompt_len, max_new, rng))

        # ---- steady state: same pattern again; rebuilds must not move.
        rebuilds0 = eng.plan_cache.rebuilds
        steps0 = eng.stats["decode_steps"]
        t0 = time.perf_counter()
        outs = eng.run(_requests(cfg, max_batch, prompt_len, max_new, rng))
        wall = time.perf_counter() - t0
        steady_steps = eng.stats["decode_steps"] - steps0
        plan_rebuilds = eng.plan_cache.rebuilds - rebuilds0
        n_tok = sum(len(o) for o in outs.values())
        tok_s = n_tok / max(wall, 1e-9)

        # ---- per-step decode latency: two always-live lanes, 1-step bursts.
        lat_steps = 12 if quick else 48
        for r in _requests(cfg, 2, prompt_len, lat_steps + 8, rng):
            eng.submit(r)
        while eng.sched or eng._partial is not None:
            eng._admit()
            eng._prefill_one_chunk()
            if eng._partial.start >= len(eng._partial.req.prompt):
                eng._finish_prefill()
        eng.decode_burst(steps=1)              # compile the (cap=2, 1) burst
        lat_us = []
        for _ in range(lat_steps):
            t0 = time.perf_counter()
            eng.decode_burst(steps=1)          # includes the host readback
            lat_us.append((time.perf_counter() - t0) * 1e6)
        while eng.has_work():                  # drain the latency lanes
            eng.step()

        # ---- prefill chunk latency (the disaggregation quantum)
        pre = _requests(cfg, 1, prompt_len, 2, rng)[0]
        eng.submit(pre)
        eng._admit()
        t0 = time.perf_counter()
        eng._prefill_one_chunk()
        jax.block_until_ready(eng._partial.logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3
        eng._finish_prefill()
        while eng.has_work():
            eng.step()

        plan_counters = eng.plan_cache.counters()
        plan_report = _decode_plan_report(eng.plan_cache)
    finally:
        eng.close()

    payload = {
        "config": {"arch": cfg.name, "backend": jax.default_backend(),
                   "max_batch": max_batch, "prompt_len": prompt_len,
                   "max_new": max_new, "burst_steps": burst,
                   "page_size": 8, "prefill_chunk": 8,
                   "capture_sizes": list(eng.capture_sizes),
                   "note": "pallas kernels run in interpret mode off-TPU"},
        "throughput": {"tok_s": round(tok_s, 2), "tokens": n_tok,
                       "wall_s": round(wall, 4)},
        "decode_step_us": {"p50": round(float(np.percentile(lat_us, 50)), 1),
                           "p99": round(float(np.percentile(lat_us, 99)), 1),
                           "n": len(lat_us)},
        "prefill_ms": round(prefill_ms, 3),
        "plan_rebuilds": plan_rebuilds,
        "steady_steps": steady_steps,
        "plan_cache": plan_counters,
        "engine_stats": eng.stats,
        "decode_plan": plan_report,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)

    if steady_steps < STEADY_STEPS:
        raise AssertionError(
            f"steady-state window too short: {steady_steps} < {STEADY_STEPS}")
    if plan_rebuilds != 0:
        raise AssertionError(
            f"{plan_rebuilds} decode-plan rebuilds at steady state (want 0)")

    dd = payload["decode_plan"].get("dedup_gather", {})
    rows = [
        f"serve/tok_s,{payload['decode_step_us']['p50']},"
        f"tok_s={payload['throughput']['tok_s']};"
        f"tokens={n_tok};wall_s={payload['throughput']['wall_s']}",
        f"serve/decode_step,{payload['decode_step_us']['p50']},"
        f"p99={payload['decode_step_us']['p99']};n={len(lat_us)}",
        f"serve/prefill_chunk,{prefill_ms * 1e3:.1f},ms={prefill_ms}",
        f"serve/steady,{steady_steps},plan_rebuilds={plan_rebuilds};"
        f"plan_cache={plan_counters}",
    ]
    if dd:
        rows.append(
            f"serve/decode_dma,{dd['run_batched']},"
            f"batching_factor={dd['batching_factor']};"
            f"unique_rows={dd['unique_rows']};per_row={dd['per_row']}")
    rows.append(f"# wrote {out_path}; steady {steady_steps} steps with "
                f"{plan_rebuilds} plan rebuilds; "
                f"{payload['throughput']['tok_s']} tok/s")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for row in run(args.out, quick=not args.full):
        print(row)


if __name__ == "__main__":
    main()
