"""The unified selection -> planned-execution layer (core/dispatch.py).

Covers the PR-5 refactor contract:
  - PKM value aggregation and the top-K MLP's down-projection lower to the
    shared ``weighted_value_sum`` primitive (GatherPlan + streamed row-DMA
    gather kernels) and match their dense references forward AND backward,
    plus the ``pkm_full_scores`` oracle.
  - The capability chain pallas_fused -> pallas -> einsum degrades
    identically on unsupported shapes for every approximator.
  - Tripwires: the planned rungs never materialize the dense (N, S, d) value
    gather (``dispatch.dense_value_gather``) nor the dense masked
    down-projection (``topk_mlp._down_dense``) — and they really do go
    through the streamed gather kernel.
  - The uniform aux contract of the FFN registry (models/ffn.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import moe_ffn
from repro.configs.base import FFNConfig
from repro.core import (apply_dense, apply_moe, apply_pkm, init_dense,
                        init_moe, init_pkm, pkm_full_scores, pkm_select,
                        value_sum_path)
from repro.core import dispatch, topk_mlp
from repro.kernels import cvmm, ops

D = 32
PLANNED = ("pallas_fused_interpret", "pallas_interpret", "einsum")


def _pkm_cfg(impl="auto", **kw):
    kw.setdefault("n_subkeys", 8)
    kw.setdefault("pkm_heads", 2)
    kw.setdefault("pkm_knn", 4)
    kw.setdefault("activation", "relu")
    return FFNConfig(kind="pkm", impl=impl, **kw)


def _topk_cfg(impl="auto", **kw):
    kw.setdefault("d_ff", 64)
    kw.setdefault("topk_k", 8)
    kw.setdefault("activation", "relu")
    return FFNConfig(kind="topk", impl=impl, **kw)


# ---------------------------------------------------------------------------
# GatherPlan / gathered_weighted_sum (ops level)
# ---------------------------------------------------------------------------

def _gws_reference(values, idx, weights, n_tokens):
    return jnp.einsum("ns,nsd->nd", weights.astype(values.dtype), values[idx])


def test_gather_plan_layout():
    """row_src/tok_src/weight_tiles describe the same flat selection; slack
    slots carry sentinels and zero weight; the run table replays the gather."""
    n, s, r = 50, 6, 37
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (n, s), 0, r)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, s))
    plan = ops.make_gather_plan(idx, w, r)
    m = n * s
    assert plan.m_pad % ops.TM == 0 and plan.m_pad >= m
    row_src = np.asarray(plan.row_src)
    tok_src = np.asarray(plan.tok_src)
    wt = np.asarray(plan.weight_tiles).reshape(-1)
    np.testing.assert_array_equal(row_src[:m], np.asarray(idx).reshape(-1))
    np.testing.assert_array_equal(tok_src[:m],
                                  np.repeat(np.arange(n), s))
    np.testing.assert_allclose(wt[:m], np.asarray(w).reshape(-1), rtol=1e-6)
    assert (row_src[m:] == r).all() and (tok_src[m:] == n).all()
    assert (wt[m:] == 0).all()
    # the run table drives the streamed kernel to exactly take-with-zero-fill
    vals = jax.random.normal(jax.random.PRNGKey(2), (r, 128))
    got = cvmm.cvmm_gather_rows_pallas(vals, plan.row_src, plan.run_start,
                                       plan.run_off, interpret=True)
    want = jnp.take(vals, plan.row_src, axis=0, mode="fill", fill_value=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fuse_weights", [True, False])
def test_gathered_weighted_sum_matches_reference(dtype, fuse_weights):
    n, s, r, d = 45, 5, 20, 24
    idx = jax.random.randint(jax.random.PRNGKey(0), (n, s), 0, r)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, s), jnp.float32)
    vals = jax.random.normal(jax.random.PRNGKey(2), (r, d),
                             jnp.float32).astype(dtype)
    plan = ops.make_gather_plan(idx, w, r)
    got = ops.gathered_weighted_sum(vals, plan, n, fuse_weights=fuse_weights,
                                    interpret=True)
    want = _gws_reference(vals, idx, w, n)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_gathered_weighted_sum_grads_match_reference():
    n, s, r, d = 30, 4, 16, 24
    idx = jax.random.randint(jax.random.PRNGKey(0), (n, s), 0, r)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, s), jnp.float32)
    vals = jax.random.normal(jax.random.PRNGKey(2), (r, d), jnp.float32)
    probe = lambda y: jnp.sum(y * jnp.cos(jnp.arange(y.size).reshape(y.shape)))

    def loss(vals, w):
        plan = ops.make_gather_plan(idx, w, r)
        return probe(ops.gathered_weighted_sum(vals, plan, n, interpret=True))

    gv, gw = jax.grad(loss, argnums=(0, 1))(vals, w)
    rv, rw = jax.grad(lambda v, w: probe(_gws_reference(v, idx, w, n)),
                      argnums=(0, 1))(vals, w)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# PKM via the planned layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", PLANNED)
@pytest.mark.parametrize("relu", [True, False])
def test_pkm_planned_matches_dense(impl, relu):
    """Every chain rung == the dense (N, H, K, d) take+einsum reference."""
    cfg = _pkm_cfg(activation="relu" if relu else "softmax")
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, D))
    yd, _ = apply_pkm(p, x, dataclasses.replace(cfg, impl="dense"))
    yp, _ = apply_pkm(p, x, dataclasses.replace(cfg, impl=impl))
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yp),
                               atol=1e-5, rtol=1e-5)


def test_pkm_planned_grads_match_dense():
    """fwd+bwd parity: gradients wrt keys, values AND the input flow through
    the GatherPlan (weight_tiles -> retrieval scores) exactly as through the
    dense reference."""
    cfg = _pkm_cfg(impl="pallas_fused_interpret")
    cfg_d = dataclasses.replace(cfg, impl="dense")
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, D))
    probe = lambda y: jnp.sum(y * jnp.cos(jnp.arange(y.size).reshape(y.shape)))
    gp, gx = jax.grad(lambda p, x: probe(apply_pkm(p, x, cfg)[0]),
                      argnums=(0, 1))(p, x)
    rp, rx = jax.grad(lambda p, x: probe(apply_pkm(p, x, cfg_d)[0]),
                      argnums=(0, 1))(p, x)
    for name in rp:
        np.testing.assert_allclose(np.asarray(gp[name]), np.asarray(rp[name]),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               atol=1e-4, rtol=1e-4)


def test_pkm_planned_matches_full_scores_oracle():
    """Aggregating the true top-K of the FULL score vector (the O(N*ns^2)
    oracle) == the planned product-key path, per head: the Cartesian
    retrieval provably contains the true top-K (Sec. 3.2), so the whole
    pipeline — retrieval + planned aggregation — must reproduce the oracle."""
    cfg = _pkm_cfg(impl="pallas_fused_interpret")
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    full = pkm_full_scores(p, x, cfg)                        # (N, H, ns^2)
    top, vidx = jax.lax.top_k(full, cfg.pkm_knn)             # true top-K
    w = jax.nn.relu(top)
    want = jnp.einsum("nhk,nhkd->nd", w, p["values"][vidx])
    got, _ = apply_pkm(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_pkm_planned_never_materializes_dense_gather(monkeypatch):
    """Acceptance tripwire: on the planned rungs no (N, S, d) dense value
    gather may be materialized — and the streamed gather kernel must actually
    be what executes the aggregation."""
    def boom(*a, **kw):
        raise AssertionError("planned path materialized the dense value gather")

    called = {"kernel": 0}
    orig = cvmm.cvmm_gather_rows_pallas

    def spy(*a, **kw):
        called["kernel"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(dispatch, "dense_value_gather", boom)
    monkeypatch.setattr(cvmm, "cvmm_gather_rows_pallas", spy)
    monkeypatch.setattr(ops, "cvmm_gather_rows_pallas", spy)
    cfg = _pkm_cfg(impl="pallas_fused_interpret")
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    y, _ = apply_pkm(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert called["kernel"] >= 1
    g = jax.grad(lambda p: apply_pkm(p, x, cfg)[0].sum())(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# Two-stage product-key selection (C candidates per half) + million-value scale
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_candidates", [0, 6, 8])
def test_two_stage_wider_candidates_matches_oracle(n_candidates):
    """Any C >= K reproduces the full-score oracle exactly: the C*C candidate
    grid contains the true top-K, so widening C must not change the output."""
    cfg = _pkm_cfg(impl="pallas_fused_interpret", n_candidates=n_candidates)
    cfg.validate()
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    full = pkm_full_scores(p, x, cfg)
    top, vidx = jax.lax.top_k(full, cfg.pkm_knn)
    want = jnp.einsum("nhk,nhkd->nd", jax.nn.relu(top), p["values"][vidx])
    got, _ = apply_pkm(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_pkm_candidate_width_validation():
    """configs satellite: an explicit candidate width below K (the containment
    guarantee breaks) or above n_subkeys (impossible top-C) is an error with
    a message naming the constraint; unset (0) means C = K."""
    _pkm_cfg(n_candidates=6).validate()                         # K <= 6 <= ns
    assert _pkm_cfg().pkm_candidates == 4                       # default C = K
    assert _pkm_cfg(n_candidates=6).pkm_candidates == 6
    with pytest.raises(AssertionError, match="C >= K"):
        _pkm_cfg(n_candidates=2).validate()                     # 0 < C < K
    with pytest.raises(AssertionError, match="n_subkeys"):
        _pkm_cfg(n_candidates=16).validate()                    # C > ns


def test_pkm_selection_scales_to_million_values():
    """Acceptance: selection at n_values >= 1M (ns=1024) without the
    (n_tokens, n_values) score matrix. With few tokens the full grid oracle
    is still affordable — the two-stage top-K must match it exactly."""
    cfg = _pkm_cfg(n_subkeys=1024, pkm_knn=4, n_candidates=16)
    cfg.validate()
    assert cfg.n_values == 1 << 20
    h, half = cfg.pkm_heads, D // 2
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    p = {"keys_a": jax.random.normal(ka, (h, half, 1024)) * 0.02,
         "keys_b": jax.random.normal(kb, (h, half, 1024)) * 0.02}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, D))
    sel = pkm_select(p, x, cfg)
    assert sel.n_items == 1 << 20
    assert sel.idx.shape == (2, h * cfg.pkm_knn)
    # oracle: the full (2, H, ns^2) grid — affordable only because N=2
    full = pkm_full_scores(p, x, cfg)
    top, vidx = jax.lax.top_k(full, cfg.pkm_knn)
    want_w = np.sort(np.asarray(jax.nn.relu(top)).reshape(2, -1), axis=-1)
    got_w = np.sort(np.asarray(sel.weights), axis=-1)
    np.testing.assert_allclose(got_w, want_w, atol=1e-5, rtol=1e-5)
    # the selected ids agree wherever the weight is alive (relu may zero ties)
    want_ids = set(np.asarray(vidx).reshape(-1).tolist())
    got_alive = np.asarray(sel.idx).reshape(-1)[
        np.asarray(sel.weights).reshape(-1) > 0]
    assert set(got_alive.tolist()) <= want_ids


def test_pkm_million_value_dedup_aggregation(monkeypatch):
    """Acceptance: the whole pipeline at n_values >= 1M — two-stage selection
    + dedup-plan streamed aggregation over a (2^20, d) bf16 value table —
    runs with neither the dense (N, S, d)-from-score-matrix path nor the
    dense value gather, and matches the dense oracle on the same selection."""
    def boom(*a, **kw):
        raise AssertionError("million-value path materialized a dense gather")

    monkeypatch.setattr(dispatch, "dense_value_gather", boom)
    cfg = _pkm_cfg(impl="pallas_fused_interpret", n_subkeys=1024, pkm_knn=4,
                   n_candidates=8)
    d = 64
    h, half = cfg.pkm_heads, d // 2
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    p = {"keys_a": jax.random.normal(ka, (h, half, 1024)) * 0.05,
         "keys_b": jax.random.normal(kb, (h, half, 1024)) * 0.05}
    # deterministic-pattern bf16 table built by broadcast-add (no 1M-row PRNG)
    rows = jnp.arange(cfg.n_values, dtype=jnp.float32)[:, None]
    cols = jnp.arange(d, dtype=jnp.float32)[None, :]
    values = (jnp.sin(rows * 1e-3) + jnp.cos(cols)).astype(jnp.bfloat16)
    p["values"] = values
    # bf16 input end-to-end: apply_pkm casts the table to x.dtype, and a f32
    # x would force a 256MB f32 copy of the 1M-row table
    x = jax.random.normal(jax.random.PRNGKey(1), (4, d)).astype(jnp.bfloat16)
    y, _ = apply_pkm(p, x, cfg)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    # dense oracle on the SAME (tiny) selection: only S rows are ever read
    sel = pkm_select(p, x, cfg)
    want = jnp.einsum("ns,nsd->nd", sel.weights.astype(jnp.float32),
                      jnp.take(values, sel.idx, axis=0).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(want),
                               atol=0.1, rtol=0.1)


# ---------------------------------------------------------------------------
# Top-K MLP sparse down-projection via the planned layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", PLANNED)
@pytest.mark.parametrize("activation", ["relu", "gelu"])
def test_topk_sparse_down_matches_dense(impl, activation):
    """The sparse down-projection (K selected W2 rows through the planned
    gather-sum) == the masked full (..., d_ff) @ W2 reference, including for
    activations with negative surviving values (gelu)."""
    cfg = _topk_cfg(activation=activation)
    p = init_dense(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 7, D))
    yd, _ = apply_dense(p, x, dataclasses.replace(cfg, impl="dense"))
    yp, _ = apply_dense(p, x, dataclasses.replace(cfg, impl=impl))
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yp),
                               atol=1e-5, rtol=1e-5)


def test_topk_sparse_down_grads_match_dense():
    cfg = _topk_cfg(impl="pallas_fused_interpret")
    cfg_d = dataclasses.replace(cfg, impl="dense")
    p = init_dense(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (20, D))
    probe = lambda y: jnp.sum(y * jnp.sin(jnp.arange(y.size).reshape(y.shape)))
    gp, gx = jax.grad(lambda p, x: probe(apply_dense(p, x, cfg)[0]),
                      argnums=(0, 1))(p, x)
    rp, rx = jax.grad(lambda p, x: probe(apply_dense(p, x, cfg_d)[0]),
                      argnums=(0, 1))(p, x)
    for name in rp:
        np.testing.assert_allclose(np.asarray(gp[name]), np.asarray(rp[name]),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               atol=1e-4, rtol=1e-4)


def test_topk_planned_never_runs_dense_down(monkeypatch):
    """Tripwire: the planned top-K path must not fall back to the dense
    masked down-projection nor the dense value gather."""
    def boom(*a, **kw):
        raise AssertionError("planned top-K ran the dense down-projection")

    monkeypatch.setattr(topk_mlp, "_down_dense", boom)
    monkeypatch.setattr(dispatch, "dense_value_gather", boom)
    cfg = _topk_cfg(impl="pallas_fused_interpret")
    p = init_dense(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    y, _ = apply_dense(p, x, cfg)
    g = jax.grad(lambda p: apply_dense(p, x, cfg)[0].sum())(p)
    assert np.isfinite(np.asarray(y)).all()
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_topk_equals_dense_mlp_when_k_is_dff():
    """K = d_ff: the planned sparse path degenerates to the plain dense MLP."""
    cfg_t = _topk_cfg(impl="pallas_fused_interpret", topk_k=64)
    cfg_d = FFNConfig(kind="dense", d_ff=64, activation="relu")
    p = init_dense(jax.random.PRNGKey(0), D, cfg_d, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    yt, _ = apply_dense(p, x, cfg_t)
    yd, _ = apply_dense(p, x, cfg_d)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(yd),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Capability fallback chain — identical degradation for every approximator
# ---------------------------------------------------------------------------

def test_fallback_chain_degrades_identically(monkeypatch):
    """Starve VMEM so no streamed tile fits: every approximator on a
    pallas(_fused) impl must degrade to its XLA rung (einsum take+sum for the
    weighted-value primitives, ragged grouped matmul for MoE) with identical
    numerics — never a trace-time error, never a kernel launch."""
    def boom(*a, **kw):
        raise AssertionError("kernel launched despite failing capability gate")

    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))

    # references on untouched budget
    cfg_p = _pkm_cfg()
    pp = init_pkm(jax.random.PRNGKey(0), D, cfg_p, 2)
    yp_ref, _ = apply_pkm(pp, x, dataclasses.replace(cfg_p, impl="einsum"))
    cfg_t = _topk_cfg()
    pt = init_dense(jax.random.PRNGKey(0), D, cfg_t, 2)
    yt_ref, _ = apply_dense(pt, x, dataclasses.replace(cfg_t, impl="einsum"))
    cfg_m = moe_ffn(4, 16, 2, dispatch="sort")
    pm = init_moe(jax.random.PRNGKey(0), D, cfg_m, 2)
    ym_ref, _ = apply_moe(pm, x, dataclasses.replace(cfg_m, impl="ragged"))

    monkeypatch.setattr(cvmm, "VMEM_BUDGET", 1 << 10)
    assert not ops.gather_supported(D)
    assert not ops.pallas_supported(D, cfg_m.expert_size)
    monkeypatch.setattr(cvmm, "cvmm_gather_rows_pallas", boom)
    monkeypatch.setattr(ops, "cvmm_gather_rows_pallas", boom)
    monkeypatch.setattr(ops, "moe_mlp_fused", boom)
    monkeypatch.setattr(ops, "cvmm_planned", boom)

    for impl in ("pallas_fused_interpret", "pallas_interpret"):
        yp, _ = apply_pkm(pp, x, dataclasses.replace(cfg_p, impl=impl))
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yp_ref),
                                   atol=1e-6, err_msg=f"pkm/{impl}")
        yt, _ = apply_dense(pt, x, dataclasses.replace(cfg_t, impl=impl))
        np.testing.assert_allclose(np.asarray(yt), np.asarray(yt_ref),
                                   atol=1e-6, err_msg=f"topk/{impl}")
        ym, _ = apply_moe(pm, x, dataclasses.replace(cfg_m, impl=impl))
        np.testing.assert_allclose(np.asarray(ym), np.asarray(ym_ref),
                                   atol=1e-5, rtol=1e-5, err_msg=f"moe/{impl}")


def test_value_sum_path_reporting(monkeypatch):
    """value_sum_path mirrors the chain weighted_value_sum takes."""
    assert value_sum_path(_pkm_cfg(impl="pallas_fused_interpret"), D) == \
        "pallas_fused"
    assert value_sum_path(_pkm_cfg(impl="pallas_interpret"), D) == "pallas"
    assert value_sum_path(_pkm_cfg(impl="einsum"), D) == "einsum"
    assert value_sum_path(_pkm_cfg(impl="dense"), D) == "dense"
    monkeypatch.setattr(cvmm, "VMEM_BUDGET", 1 << 10)
    assert value_sum_path(_pkm_cfg(impl="pallas_fused_interpret"), D) == \
        "einsum"


def test_impl_knob_overrides_global_default(monkeypatch):
    """cfg.impl forces the rung regardless of ops.default_impl(); "auto"
    defers to it (set_default_impl still honored)."""
    called = {"n": 0}
    orig = ops.gathered_weighted_sum_dedup

    def spy(*a, **kw):
        called["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(ops, "gathered_weighted_sum_dedup", spy)
    cfg = _pkm_cfg(impl="einsum")
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
    apply_pkm(p, x, cfg)
    assert called["n"] == 0                      # einsum rung: no planned call
    ops.set_default_impl("pallas_fused_interpret")
    try:
        apply_pkm(p, x, dataclasses.replace(cfg, impl="auto"))
    finally:
        ops.set_default_impl(None)
    assert called["n"] == 1                      # auto deferred to the default


# ---------------------------------------------------------------------------
# Uniform aux contract (models/ffn.py registry)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    FFNConfig(kind="dense", d_ff=64),
    FFNConfig(kind="glu", d_ff=64, activation="silu"),
    _topk_cfg(),
    _pkm_cfg(),
    moe_ffn(4, 16, 2, dispatch="sort", reg_gamma=0.01),
    FFNConfig(kind="none"),
], ids=lambda c: c.kind)
def test_registry_uniform_aux_contract(cfg):
    """Every approximator returns the same aux keys; collect_stats adds a
    usage histogram for every *selecting* approximator (MoE experts, PKM
    values, top-K channels) — nothing is re-fabricated per branch."""
    from repro.models.ffn import apply_ffn, init_ffn

    cfg.validate()
    p = init_ffn(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 5, D))
    y, aux = apply_ffn(p, x, cfg, rng=jax.random.PRNGKey(2), train=True)
    assert y.shape == x.shape
    assert set(aux) == {"moe_reg", "moe_dropped"}
    y2, aux2 = apply_ffn(p, x, cfg, collect_stats=True)
    if cfg.kind in ("topk", "pkm", "sigma_moe"):
        assert "usage" in aux2
        assert {"counts", "weight", "usage_entropy"} <= set(aux2["usage"])
    # collecting stats must not perturb the output (train=False both times)
    y_eval, _ = apply_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_eval), np.asarray(y2))


def test_pkm_usage_histogram_counts_selected_values():
    """The collect_stats histogram really counts value selections: H*K slots
    per token, counts sum to N*H*K."""
    cfg = _pkm_cfg()
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    _, aux = apply_pkm(p, x, cfg, collect_stats=True)
    st = aux["usage"]
    assert st["counts"].shape == (cfg.n_values,)
    assert float(st["counts"].sum()) == 16 * cfg.pkm_heads * cfg.pkm_knn
    sel = pkm_select(p, x, cfg)
    want = np.bincount(np.asarray(sel.idx).reshape(-1),
                       minlength=cfg.n_values)
    np.testing.assert_array_equal(np.asarray(st["counts"], np.int64), want)


def test_pkm_config_rejects_stale_d_ff():
    """configs satellite: a d_ff that disagrees with n_subkeys**2 is an error
    (a stale value would silently mis-scale the dense-equivalent init)."""
    FFNConfig(kind="pkm", n_subkeys=8, d_ff=64).validate()      # agrees
    FFNConfig(kind="pkm", n_subkeys=8).validate()               # unset: fine
    with pytest.raises(AssertionError):
        FFNConfig(kind="pkm", n_subkeys=8, d_ff=100).validate()
