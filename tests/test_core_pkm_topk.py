"""PKM + Top-K activation tests, including the paper's key structural guarantee and
hypothesis property tests.

`hypothesis` is an OPTIONAL dev dependency (requirements-dev.txt): the property
test is skipped when it is missing, and a deterministic non-hypothesis smoke
sweep covers the same containment property either way."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # module-level importorskip would hide the tests below;
    HAVE_HYPOTHESIS = False  # the property test reports as an explicit skip

from repro.configs.base import FFNConfig
from repro.core import apply_dense, apply_pkm, init_dense, init_pkm, pkm_full_scores

D = 32


def test_topk_masks_to_k_nonzeros():
    cfg = FFNConfig(kind="topk", d_ff=64, topk_k=8, activation="relu")
    p = init_dense(jax.random.PRNGKey(0), D, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    u = jax.nn.relu(x @ p["w1"])
    kth = jax.lax.top_k(u, 8)[0][..., -1:]
    kept = (u >= kth) & (u > 0)
    # the masked activation keeps at most K entries per token
    assert int(kept.sum(-1).max()) <= 8


def test_topk_equals_dense_when_k_is_dff():
    cfg_t = FFNConfig(kind="topk", d_ff=64, topk_k=64, activation="relu")
    cfg_d = FFNConfig(kind="dense", d_ff=64, activation="relu")
    p = init_dense(jax.random.PRNGKey(0), D, cfg_d, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    yt, _ = apply_dense(p, x, cfg_t)
    yd, _ = apply_dense(p, x, cfg_d)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(yd), atol=1e-6)


def _pkm(ns=8, knn=4, heads=2, relu=True):
    cfg = FFNConfig(kind="pkm", n_subkeys=ns, pkm_heads=heads, pkm_knn=knn,
                    activation="relu" if relu else "softmax")
    p = init_pkm(jax.random.PRNGKey(0), D, cfg, 2)
    return cfg, p


def test_pkm_topk_superset_guarantee():
    """Paper Sec. 3.2: top-K over the K^2 Cartesian candidates == true top-K of the
    full u (the candidates provably contain the true top-K)."""
    cfg, p = _pkm(ns=8, knn=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, D))
    full = pkm_full_scores(p, x, cfg)                 # (N, H, ns^2)
    true_top = jax.lax.top_k(full, cfg.pkm_knn)[0]

    xa, xb = jnp.split(x, 2, -1)
    ua = jnp.einsum("nd,hds->nhs", xa, p["keys_a"])
    ub = jnp.einsum("nd,hds->nhs", xb, p["keys_b"])
    va, _ = jax.lax.top_k(ua, cfg.pkm_knn)
    vb, _ = jax.lax.top_k(ub, cfg.pkm_knn)
    cand = (va[..., :, None] + vb[..., None, :]).reshape(32, cfg.pkm_heads, -1)
    cand_top = jax.lax.top_k(cand, cfg.pkm_knn)[0]
    np.testing.assert_allclose(np.asarray(cand_top), np.asarray(true_top),
                               atol=1e-5, rtol=1e-5)


def _check_superset_property(ns: int, knn: int, seed: int):
    """For random sub-key scores, Cartesian top-K == full top-K (Sec. 3.2)."""
    knn = min(knn, ns)
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    ua = jax.random.normal(ka, (ns,))
    ub = jax.random.normal(kb, (ns,))
    full = (ub[:, None] + ua[None, :]).reshape(-1)
    true_top = np.sort(np.asarray(jax.lax.top_k(full, knn)[0]))[::-1]
    va = jax.lax.top_k(ua, knn)[0]
    vb = jax.lax.top_k(ub, knn)[0]
    cand = (va[:, None] + vb[None, :]).reshape(-1)
    cand_top = np.sort(np.asarray(jax.lax.top_k(cand, knn)[0]))[::-1]
    np.testing.assert_allclose(cand_top, true_top, atol=1e-6)


def test_pkm_superset_smoke():
    """Deterministic sweep of the containment property (no hypothesis needed)."""
    for ns, knn, seed in [(2, 1, 0), (4, 2, 7), (8, 4, 1), (12, 6, 2),
                          (5, 3, 123), (9, 1, 42)]:
        _check_superset_property(ns, knn, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 12), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
    def test_pkm_superset_property(ns, knn, seed):
        """Hypothesis: for random sub-key scores, Cartesian top-K == full top-K."""
        _check_superset_property(ns, knn, seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_pkm_superset_property():
        pass


def test_pkm_forward_shapes_and_grads():
    for relu in (True, False):
        cfg, p = _pkm(relu=relu)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, D))
        y, _ = apply_pkm(p, x, cfg)
        assert y.shape == x.shape
        g = jax.grad(lambda p: apply_pkm(p, x, cfg)[0].sum())(p)
        for leaf in jax.tree_util.tree_leaves(g):
            assert np.isfinite(np.asarray(leaf)).all()


def test_pkm_relu_sparser_output_than_softmax():
    """ReLU zeroes negative candidate scores; softmax never does."""
    cfg_r, p = _pkm(relu=True)
    cfg_s = dataclasses.replace(cfg_r, activation="softmax")
    x = jax.random.normal(jax.random.PRNGKey(1), (64, D)) * 0.01  # small scores
    yr, _ = apply_pkm(p, x, cfg_r)
    ys, _ = apply_pkm(p, x, cfg_s)
    # with tiny inputs ReLU output is ~0 while softmax mixes values regardless
    assert float(jnp.abs(yr).mean()) < float(jnp.abs(ys).mean())
