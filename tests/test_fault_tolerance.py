"""Fault-tolerance integration: preemption mid-run + bit-exact resume, injected
failure recovery, straggler detection."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")


def _train(args, check=True):
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    r = subprocess.run(cmd, env=ENV, cwd=REPO, capture_output=True, text=True,
                       timeout=480)
    if check and r.returncode != 0:
        raise AssertionError(f"train failed:\n{r.stdout}\n{r.stderr}")
    return r


def _final_loss(out: str) -> float:
    lines = [l for l in out.splitlines() if l.startswith("step")]
    return float(lines[-1].split("loss")[1].split()[0])


@pytest.mark.slow
def test_preemption_resume_bit_exact(tmp_path):
    """Uninterrupted run == (run killed at step 6 -> resumed): same final loss."""
    common = ["--arch", "wt103-47m-moe", "--reduced", "--steps", "12",
              "--batch", "4", "--seq", "32", "--ckpt-every", "6",
              "--log-every", "1", "--seed", "3"]
    r_full = _train(common + ["--ckpt-dir", str(tmp_path / "a")])
    loss_full = _final_loss(r_full.stdout)

    # interrupted run: injected failure at step 6 (after the step-6 checkpoint)
    r_fail = _train(common + ["--ckpt-dir", str(tmp_path / "b"),
                              "--fail-at-step", "6"], check=False)
    assert r_fail.returncode != 0
    r_resume = _train(common + ["--ckpt-dir", str(tmp_path / "b"), "--resume"])
    assert "[resume] restored step 6" in r_resume.stdout
    loss_resumed = _final_loss(r_resume.stdout)
    np.testing.assert_allclose(loss_resumed, loss_full, rtol=1e-5)


@pytest.mark.slow
def test_training_decreases_loss(tmp_path):
    r = _train(["--arch", "llama3-8b", "--reduced", "--ffn", "sigma_moe",
                "--steps", "30", "--batch", "8", "--seq", "64",
                "--lr", "3e-3", "--log-every", "1", "--ckpt-every", "0",
                "--ckpt-dir", str(tmp_path)])
    lines = [l for l in r.stdout.splitlines() if l.startswith("step")]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first - 0.25, r.stdout


def test_straggler_monitor_flags_outliers():
    from repro.runtime.monitor import StragglerMonitor
    import time
    flagged = []
    mon = StragglerMonitor(threshold=3.0, warmup_steps=2,
                           on_straggler=lambda s, dt, mu: flagged.append(s))
    for step in range(8):
        mon.start()
        time.sleep(0.01 if step != 6 else 0.2)
        mon.stop(step)
    assert flagged == [6]
