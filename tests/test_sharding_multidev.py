"""Multi-device SPMD tests: run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device jax config.

Covers: logical sharding rules, sharded train step == single-device train step,
shard_map MoE EP path == einsum path, small-mesh dry-run end-to-end.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)], env=env,
                       cwd=REPO, capture_output=True, text=True, timeout=480)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_logical_rules_basic():
    from jax.sharding import PartitionSpec as P
    import jax
    from repro.sharding.logical import spec_for_axes, TRAIN_RULES
    assert spec_for_axes(("experts", "embed", "expert_ff"), TRAIN_RULES,
                         None) == P(None, None, None)


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced
    from repro.configs.base import OptimizerConfig
    from repro.models import build_model
    from repro.runtime.steps import init_train_state, make_train_step
    from repro.sharding import TRAIN_RULES, mesh_context, tree_shardings

    cfg = reduced("llama3-8b")
    model = build_model(cfg)
    opt = OptimizerConfig(lr=1e-3)
    step = make_train_step(model, opt)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                          cfg.vocab_size)}
    rng = jax.random.PRNGKey(2)

    # single device
    state1 = init_train_state(model, key, opt)
    s1, m1 = jax.jit(step)(state1, batch, rng)

    # 4x2 mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh_context(mesh):
        state2 = init_train_state(model, key, opt)
        state2 = jax.device_put(state2, tree_shardings(state2, mesh, TRAIN_RULES))
        s2, m2 = jax.jit(step)(state2, batch, rng)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-4)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)
    print("SHARDED==SINGLE OK")
    """)


@pytest.mark.slow
def test_shard_map_moe_matches_einsum():
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import moe_ffn
    from repro.core import apply_moe, init_moe
    from repro.sharding import mesh_context, tree_shardings, TRAIN_RULES
    from jax.sharding import NamedSharding, PartitionSpec as P

    d, ne, g, k = 32, 8, 16, 2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg_e = moe_ffn(ne, g, k, dispatch="einsum", capacity_factor=8.0)
    cfg_s = dataclasses.replace(cfg_e, dispatch="shard_map")
    p = init_moe(jax.random.PRNGKey(1), d, cfg_e, n_layers=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, d))
    with mesh_context(mesh):
        pp = jax.device_put(p, tree_shardings(p, mesh, TRAIN_RULES))
        xx = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        ye, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg_e))(pp, xx)
        ys, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg_s))(pp, xx)
        # gradients through the shard_map path
        gs = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg_s)[0].sum()))(pp, xx)
        ge = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg_e)[0].sum()))(pp, xx)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys), atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(ge), jax.tree_util.tree_leaves(gs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    print("SHARD_MAP==EINSUM OK")
    """)


@pytest.mark.slow
def test_small_mesh_dryrun_all_modes():
    """End-to-end mini dry-run: 4x2 mesh, one arch, train+prefill+decode lower and
    compile; roofline report extracted."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import reduced, SHAPES, ShapeConfig
    from repro.configs.base import OptimizerConfig
    from repro.models import build_model
    from repro.roofline import analyze_compiled
    from repro.runtime.steps import init_train_state, make_train_step
    from repro.sharding import TRAIN_RULES, SERVE_RULES, mesh_context, tree_shardings

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = reduced("granite-moe-3b-a800m")
    model = build_model(cfg, remat="full", ep_degree=2)
    shp = ShapeConfig("mini_train", 64, 8, "train")

    with mesh_context(mesh):
        def sds(tree, rules):
            sh = tree_shardings(tree, mesh, rules)
            return jax.tree_util.tree_map(
                lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
                tree, sh)
        inputs = sds(model.input_specs(shp), TRAIN_RULES)
        state = sds(jax.eval_shape(
            lambda k: init_train_state(model, k, OptimizerConfig()),
            jax.random.PRNGKey(0)), TRAIN_RULES)
        step = make_train_step(model, OptimizerConfig())
        comp = jax.jit(step).lower(state, inputs,
                                   jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
        rep = analyze_compiled(comp, arch="granite-mini", shape=shp,
                               mesh_name="4x2", n_chips=8, cfg=cfg)
        assert rep.flops > 0 and rep.hbm_bytes > 0
        assert comp.memory_analysis() is not None

        # decode
        params = sds(jax.eval_shape(model.init, jax.random.PRNGKey(0)), SERVE_RULES)
        cache = sds(jax.eval_shape(lambda: model.init_cache(8, 64)), SERVE_RULES)
        tok = jax.ShapeDtypeStruct((8,), jnp.int32)
        dcomp = jax.jit(model.decode_step).lower(
            params, cache, tok, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        assert dcomp.memory_analysis() is not None
    print("MINI DRYRUN OK")
    """)
