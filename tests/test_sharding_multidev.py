"""Multi-device SPMD tests: run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device jax config.

Covers: logical sharding rules, sharded train step == single-device train step,
shard_map MoE EP path == einsum path, small-mesh dry-run end-to-end.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)], env=env,
                       cwd=REPO, capture_output=True, text=True, timeout=480)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_logical_rules_basic():
    from jax.sharding import PartitionSpec as P
    import jax
    from repro.sharding.logical import spec_for_axes, TRAIN_RULES
    assert spec_for_axes(("experts", "embed", "expert_ff"), TRAIN_RULES,
                         None) == P(None, None, None)


def _tiny_meshes():
    """1-device meshes carrying the production axis names: spec resolution and
    NamedSharding's duplicate-axis validation depend only on the names, so the
    whole PARAM_AXES table can be swept in-process without forcing devices."""
    import jax
    import numpy as np
    from jax.sharding import Mesh
    dev = np.array(jax.devices()[:1])
    return (Mesh(dev.reshape(1, 1), ("data", "model")),
            Mesh(dev.reshape(1, 1, 1), ("pod", "data", "model")))


def test_param_axes_sweep_no_duplicate_mesh_axis():
    """Every (name, rank) in PARAM_AXES — plain, scan-stacked and doubly
    stacked — must resolve to a spec with no repeated mesh axis under every
    rule set, on both the 2-axis and the pod 3-axis mesh. Strict mode turns
    any regression into a DuplicateMeshAxisError naming the leaf (the seed
    keys_a/keys_b crash and the shared_w* entries were exactly this)."""
    import jax
    from jax.sharding import NamedSharding
    from repro.sharding import strict_duplicate_check
    from repro.sharding.logical import (PARAM_AXES, TRAIN_RULES, SERVE_RULES,
                                        spec_for_axes)
    from repro.sharding import logical as L
    rule_sets = [TRAIN_RULES, SERVE_RULES]
    if hasattr(L, "SP_RULES"):
        rule_sets.append(L.SP_RULES)
    n = 0
    with strict_duplicate_check():
        for (name, rank), axes in PARAM_AXES.items():
            for stack in ((), ("layers",), ("layers", "layers")):
                for rules in rule_sets:
                    for mesh in _tiny_meshes():
                        spec = spec_for_axes(stack + tuple(axes), rules, mesh,
                                             path=f"{name}/{rank}")
                        NamedSharding(mesh, spec)  # would also reject repeats
                        n += 1
    assert n >= 2 * len(PARAM_AXES)


def test_duplicate_resolution_first_wins_and_strict_raises():
    import jax
    import pytest as _pytest
    from jax.sharding import PartitionSpec as P
    from repro.sharding import (DuplicateMeshAxisError, spec_for_axes,
                                strict_duplicate_check)
    from repro.sharding.logical import TRAIN_RULES
    mesh2, _ = _tiny_meshes()
    bad = dict(TRAIN_RULES, oops="model")
    # default: first occurrence keeps the mesh axis, the repeat drops to None
    assert (spec_for_axes(("ffn", "embed", "oops"), bad, mesh2)
            == P("model", "data", None))
    # tuple rules drop only the repeated member
    bad2 = dict(TRAIN_RULES, fused=("data", "model"))
    assert (spec_for_axes(("ffn", "fused"), bad2, mesh2)
            == P("model", ("data",)))
    # strict mode raises, naming the leaf path and both logical axes
    with _pytest.raises(DuplicateMeshAxisError, match=r"my_leaf.*ffn.*oops"):
        with strict_duplicate_check():
            spec_for_axes(("ffn", "embed", "oops"), bad, mesh2, path="my_leaf")
    # and can be re-disabled in a nested scope
    with strict_duplicate_check():
        with strict_duplicate_check(False):
            spec_for_axes(("ffn", "embed", "oops"), bad, mesh2, path="my_leaf")


def test_pkm_key_tables_shard_on_keys_not_heads():
    """The seed bug: keys_a/keys_b ruled both 'heads' and 'pkm_keys' onto
    'model'. The fixed table keeps heads local and shards the key dim."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.logical import PARAM_AXES, TRAIN_RULES, spec_for_axes
    mesh2, _ = _tiny_meshes()
    for name in ("keys_a", "keys_b"):
        axes = PARAM_AXES[(name, 3)]
        assert spec_for_axes(axes, TRAIN_RULES, mesh2) == P(None, "data", "model")
        # scan-stacked (rank 4) and doubly stacked (rank 5)
        assert (spec_for_axes(("layers",) + tuple(axes), TRAIN_RULES, mesh2)
                == P(None, None, "data", "model"))


def test_pod_err_leaves_get_pod_axis():
    """Error-feedback state stacked per pod ((pod,)+shape leaves under 'err')
    must shard its leading dim over the 'pod' mesh axis."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.sharding import tree_shardings
    from repro.sharding.logical import TRAIN_RULES
    _, mesh3 = _tiny_meshes()
    tree = {"params": {"blk": {"we1": jnp.zeros((4, 8, 16))}},
            "err": {"blk": {"we1": jnp.zeros((2, 4, 8, 16)),
                            "wo": jnp.zeros((1,))}}}
    sh = tree_shardings(tree, mesh3, TRAIN_RULES)
    assert sh["err"]["blk"]["we1"].spec[0] == "pod"
    assert sh["err"]["blk"]["wo"].spec == P(None)


def test_make_local_mesh_rejects_non_divisor():
    """make_local_mesh must never silently drop devices (n=1 in-process:
    model=2 cannot divide it). The 8-device divisor sweep is in the slow
    subprocess test below."""
    import pytest as _pytest
    from repro.launch.mesh import make_local_mesh
    m = make_local_mesh()                      # model=1 always divides
    assert m.axis_names == ("data", "model")
    with _pytest.raises(ValueError, match="divis"):
        make_local_mesh(model=2)


def test_compress_pod_grads_error_feedback():
    """int8 pod-path compression: expert leaves are quantized per pod with
    error feedback (residual carried, mean over pods is the DCN reduction);
    dense leaves pass through as the exact mean."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.optim import compress_pod_grads, init_compression_state
    params = {"blk": {"we1": jnp.ones((4, 8, 16)), "wo": jnp.ones((8, 8))}}
    err = init_compression_state(params, pod=2)
    assert err["blk"]["we1"].shape == (2, 4, 8, 16)
    assert err["blk"]["wo"].shape == (1,)

    k = jax.random.PRNGKey(0)
    g = {"blk": {"we1": jax.random.normal(k, (2, 4, 8, 16)),
                 "wo": jax.random.normal(k, (2, 8, 8))}}
    exact = jnp.mean(g["blk"]["we1"], 0)
    out, err = compress_pod_grads(g, err, "int8")
    np.testing.assert_allclose(np.asarray(out["blk"]["wo"]),
                               np.asarray(jnp.mean(g["blk"]["wo"], 0)),
                               rtol=1e-6)
    one_shot = float(jnp.max(jnp.abs(out["blk"]["we1"] - exact)))
    assert one_shot > 0  # int8 actually quantizes
    # same gradient repeatedly: error feedback drives the running mean of the
    # decompressed wire values toward the exact mean
    acc, steps = out["blk"]["we1"], 8
    for _ in range(steps - 1):
        out, err = compress_pod_grads(g, err, "int8")
        acc = acc + out["blk"]["we1"]
    avg_err = float(jnp.max(jnp.abs(acc / steps - exact)))
    assert avg_err < one_shot / 2


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced
    from repro.configs.base import OptimizerConfig
    from repro.models import build_model
    from repro.runtime.steps import init_train_state, make_train_step
    from repro.sharding import TRAIN_RULES, mesh_context, tree_shardings

    cfg = reduced("llama3-8b")
    model = build_model(cfg)
    opt = OptimizerConfig(lr=1e-3)
    step = make_train_step(model, opt)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                          cfg.vocab_size)}
    rng = jax.random.PRNGKey(2)

    # single device
    state1 = init_train_state(model, key, opt)
    s1, m1 = jax.jit(step)(state1, batch, rng)

    # 4x2 mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh_context(mesh):
        state2 = init_train_state(model, key, opt)
        state2 = jax.device_put(state2, tree_shardings(state2, mesh, TRAIN_RULES))
        s2, m2 = jax.jit(step)(state2, batch, rng)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-4)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)
    print("SHARDED==SINGLE OK")
    """)


@pytest.mark.slow
def test_shard_map_moe_matches_einsum():
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import moe_ffn
    from repro.core import apply_moe, init_moe
    from repro.sharding import mesh_context, tree_shardings, TRAIN_RULES
    from jax.sharding import NamedSharding, PartitionSpec as P

    d, ne, g, k = 32, 8, 16, 2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg_e = moe_ffn(ne, g, k, dispatch="einsum", capacity_factor=8.0)
    cfg_s = dataclasses.replace(cfg_e, dispatch="shard_map")
    p = init_moe(jax.random.PRNGKey(1), d, cfg_e, n_layers=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, d))
    with mesh_context(mesh):
        pp = jax.device_put(p, tree_shardings(p, mesh, TRAIN_RULES))
        xx = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        ye, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg_e))(pp, xx)
        ys, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg_s))(pp, xx)
        # gradients through the shard_map path
        gs = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg_s)[0].sum()))(pp, xx)
        ge = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg_e)[0].sum()))(pp, xx)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys), atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(ge), jax.tree_util.tree_leaves(gs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    print("SHARD_MAP==EINSUM OK")
    """)


@pytest.mark.slow
def test_pkm_state_shards_on_real_mesh():
    """The seed acceptance bug end-to-end: a real --ffn pkm train state must
    produce valid NamedShardings (strict duplicate checking on) under a
    (data=4, model=2) mesh, and make_local_mesh must reject non-divisors /
    build the 3-axis pod mesh."""
    _run("""
    import jax
    from repro.configs import reduced
    from repro.configs.base import OptimizerConfig
    from repro.models import build_model
    from repro.runtime.steps import init_train_state
    from repro.launch.mesh import make_local_mesh
    from repro.sharding import (TRAIN_RULES, mesh_context, tree_shardings,
                                strict_duplicate_check)

    # mesh construction contract on 8 devices
    m = make_local_mesh(model=2)
    assert dict(zip(m.axis_names, m.devices.shape)) == {"data": 4, "model": 2}
    m3 = make_local_mesh(model=2, pod=2)
    assert dict(zip(m3.axis_names, m3.devices.shape)) == {
        "pod": 2, "data": 2, "model": 2}
    try:
        make_local_mesh(model=3)
        raise SystemExit("model=3 on 8 devices must raise")
    except ValueError as e:
        assert "divis" in str(e)

    cfg = reduced("wt103-47m-moe").override(xl_memory=0)
    model = build_model(cfg, ffn="pkm")
    state = jax.eval_shape(
        lambda k: init_train_state(model, k, OptimizerConfig()),
        jax.random.PRNGKey(0))
    for mesh in (m, m3):
        with mesh_context(mesh), strict_duplicate_check():
            sh = tree_shardings(state, mesh, TRAIN_RULES)
            for s in jax.tree_util.tree_leaves(sh):
                pass  # NamedSharding construction inside tree_shardings
    print("PKM STATE SHARDS OK")
    """)


@pytest.mark.slow
def test_shard_map_ep_matches_sort_oracle():
    """EP shard_map dispatch == the dropless sort-path oracle, forward and
    backward, on an 8-device (data, model) mesh. capacity_factor is high so
    nothing is dropped and the two paths compute the same function; the EP
    local FFN runs through the planned-CVMM machinery (ep_plan_stats must
    report a coherent plan for the same shapes)."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import moe_ffn
    from repro.core import apply_moe, init_moe
    from repro.core.dispatch import ep_plan_stats
    from repro.sharding import mesh_context, tree_shardings, TRAIN_RULES
    from jax.sharding import NamedSharding, PartitionSpec as P

    d, ne, g, k, n = 32, 8, 16, 2, 64
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg_o = moe_ffn(ne, g, k, dispatch="sort", capacity_factor=8.0)
    cfg_s = dataclasses.replace(cfg_o, dispatch="shard_map")
    p = init_moe(jax.random.PRNGKey(1), d, cfg_o, n_layers=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    with mesh_context(mesh):
        pp = jax.device_put(p, tree_shardings(p, mesh, TRAIN_RULES))
        xx = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None)))
        yo, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg_o))(pp, xx)
        ys, aux = jax.jit(lambda p, x: apply_moe(p, x, cfg_s))(pp, xx)
        assert float(aux["moe_dropped"]) == 0.0, aux
        go = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg_o)[0].sum()))(pp, xx)
        gs = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg_s)[0].sum()))(pp, xx)
        stats = ep_plan_stats(cfg_s, n, ne, mesh)
        assert stats["e_local"] == ne // 4
        assert stats["rows_per_shard"] == stats["e_local"] * stats["capacity"] * 4
        assert stats["run_batched"] > 0
        # the EP capacity buffer is fully contiguous: whole tiles pack into
        # few descriptors, so batching must beat one-DMA-per-row
        assert stats["batching_factor"] > 1.0, stats
    np.testing.assert_allclose(np.asarray(yo), np.asarray(ys), atol=1e-5)
    for (ka, a), (kb, b) in zip(
            sorted(jax.tree_util.tree_leaves_with_path(go),
                   key=lambda kv: str(kv[0])),
            sorted(jax.tree_util.tree_leaves_with_path(gs),
                   key=lambda kv: str(kv[0]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   err_msg=str(ka))
    print("EP==SORT OK")
    """)


@pytest.mark.slow
def test_pod_tier_compressed_convergence():
    """Compressed-gradient convergence smoke on a (pod=2, data=2, model=2)
    mesh: the pod-tier int8 error-feedback path must track the exact-gradient
    run (loss and parameter divergence within tolerance over N steps), and the
    error state must be pod-stacked and pod-sharded."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced
    from repro.configs.base import OptimizerConfig
    from repro.models import build_model
    from repro.runtime.steps import init_train_state, make_train_step
    from repro.launch.mesh import make_local_mesh
    from repro.sharding import TRAIN_RULES, mesh_context, tree_shardings

    cfg = reduced("wt103-47m-moe").override(xl_memory=0)
    model = build_model(cfg, ffn="sigma_moe")
    cfg = model.cfg
    mesh = make_local_mesh(model=2, pod=2)
    steps, bsz, seq = 8, 8, 16
    key = jax.random.PRNGKey(0)

    def train(compression):
        opt = OptimizerConfig(lr=1e-3, total_steps=steps,
                              grad_compression=compression)
        with mesh_context(mesh):
            state = init_train_state(model, key, opt, pod=2)
            state = jax.device_put(state,
                                   tree_shardings(state, mesh, TRAIN_RULES))
            step = jax.jit(make_train_step(model, opt, mesh=mesh))
            losses = []
            for s in range(steps):
                tokens = jax.random.randint(jax.random.fold_in(key, 100 + s),
                                            (bsz, seq + 1), 0, cfg.vocab_size)
                state, m = step(state, {"tokens": tokens},
                                jax.random.PRNGKey(7))
                losses.append(float(m["loss"]))
            return losses, state

    l_exact, s_exact = train("none")
    l_int8, s_int8 = train("int8")

    # err leaves for expert params are pod-stacked (leading dim 2)
    from repro.optim import is_expert_leaf
    flat = jax.tree_util.tree_flatten_with_path(s_int8["err"])[0]
    n_pod = 0
    for path, leaf in flat:
        if is_expert_leaf(path):
            assert leaf.shape[0] == 2, (path, leaf.shape)
            n_pod += 1
        else:
            assert leaf.shape == (1,), (path, leaf.shape)
    assert n_pod > 0

    # convergence: compressed run tracks the exact run
    for le, li in zip(l_exact, l_int8):
        assert abs(le - li) < 0.05, (l_exact, l_int8)
    pe = jax.tree_util.tree_leaves(s_exact["params"])
    pi = jax.tree_util.tree_leaves(s_int8["params"])
    rel = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                    b.astype(jnp.float32))))
              for a, b in zip(pe, pi))
    assert rel < 5e-2, rel
    print("POD COMPRESSION CONVERGENCE OK", l_exact[-1], l_int8[-1])
    """)


@pytest.mark.slow
def test_small_mesh_dryrun_all_modes():
    """End-to-end mini dry-run: 4x2 mesh, one arch, train+prefill+decode lower and
    compile; roofline report extracted."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import reduced, SHAPES, ShapeConfig
    from repro.configs.base import OptimizerConfig
    from repro.models import build_model
    from repro.roofline import analyze_compiled
    from repro.runtime.steps import init_train_state, make_train_step
    from repro.sharding import TRAIN_RULES, SERVE_RULES, mesh_context, tree_shardings

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = reduced("granite-moe-3b-a800m")
    model = build_model(cfg, remat="full", ep_degree=2)
    shp = ShapeConfig("mini_train", 64, 8, "train")

    with mesh_context(mesh):
        def sds(tree, rules):
            sh = tree_shardings(tree, mesh, rules)
            return jax.tree_util.tree_map(
                lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
                tree, sh)
        inputs = sds(model.input_specs(shp), TRAIN_RULES)
        state = sds(jax.eval_shape(
            lambda k: init_train_state(model, k, OptimizerConfig()),
            jax.random.PRNGKey(0)), TRAIN_RULES)
        step = make_train_step(model, OptimizerConfig())
        comp = jax.jit(step).lower(state, inputs,
                                   jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
        rep = analyze_compiled(comp, arch="granite-mini", shape=shp,
                               mesh_name="4x2", n_chips=8, cfg=cfg)
        assert rep.flops > 0 and rep.hbm_bytes > 0
        assert comp.memory_analysis() is not None

        # decode
        params = sds(jax.eval_shape(model.init, jax.random.PRNGKey(0)), SERVE_RULES)
        cache = sds(jax.eval_shape(lambda: model.init_cache(8, 64)), SERVE_RULES)
        tok = jax.ShapeDtypeStruct((8,), jnp.int32)
        dcomp = jax.jit(model.decode_step).lower(
            params, cache, tok, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        assert dcomp.memory_analysis() is not None
    print("MINI DRYRUN OK")
    """)
