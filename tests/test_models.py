"""Per-arch smoke tests (reduced configs) + decode consistency + SSD oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import build_model


def _batch(cfg, b=2, s=32, seed=3):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (b, s - cfg.n_vision_tokens), 0,
                                          cfg.vocab_size)}
    if cfg.n_vision_tokens:
        batch["patches"] = jnp.zeros((b, cfg.n_vision_tokens, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(7), (b, cfg.n_audio_frames, cfg.d_model)
        ).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/backward; asserts shapes + no NaNs."""
    cfg = reduced(arch)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, met = m.loss(p, batch, rng=jax.random.PRNGKey(1), train=True)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: m.loss(p, batch, rng=jax.random.PRNGKey(1),
                                      train=True)[0])(p)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-27b", "mamba2-370m",
                                  "zamba2-7b", "granite-moe-3b-a800m",
                                  "whisper-tiny"])
def test_decode_matches_forward(arch):
    """Greedy decode with KV/SSM cache must equal the full forward logits."""
    cfg = reduced(arch)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b, total, prompt = 2, 12, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, total), 0, cfg.vocab_size)
    fb = {"tokens": toks}
    if cfg.is_encoder_decoder:
        fb["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(7), (b, cfg.n_audio_frames, cfg.d_model)
        ).astype(jnp.bfloat16)
    h, _, _ = m.forward(p, toks, frames=fb.get("frames"))
    full = m._unembed(p, h)
    cache = m.init_cache(b, 16)
    pf = dict(fb, tokens=toks[:, :prompt])
    lg, cache = m.prefill(p, pf, cache)
    errs = [float(jnp.abs(lg - full[:, prompt - 1]).max())]
    for i in range(prompt, total):
        lg, cache = m.decode_step(p, cache, toks[:, i], jnp.int32(i))
        errs.append(float(jnp.abs(lg - full[:, i]).max()))
    assert max(errs) < 2e-1, errs          # bf16 cache tolerance


def test_ssd_chunked_matches_recurrence():
    from repro.models.mamba2 import ssd_chunked, ssd_decode_step
    b, s, h, p_, g, n, chunk = 2, 29, 4, 8, 2, 6, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p_))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    Bh = jnp.repeat(B, h // g, 2)
    Ch = jnp.repeat(C, h // g, 2)
    st = jnp.zeros((b, h, p_, n))
    ys = []
    for t in range(s):
        y, st = ssd_decode_step(x[:, t], dt[:, t], A, Bh[:, t], Ch[:, t], st)
        ys.append(y)
    y_naive = jnp.stack(ys, 1)
    y_chunk, st_chunk = ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st),
                               atol=1e-4, rtol=1e-3)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention
    b, s, h, kv, dh = 2, 33, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    out = flash_attention(q, k, v, causal=True, scale=dh ** -0.5, kv_chunk=8)
    # naive
    kk = jnp.repeat(k, h // kv, 2)
    vv = jnp.repeat(v, h // kv, 2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * dh ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask, sc, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_flash_attention_sliding_window():
    from repro.models.attention import flash_attention
    b, s, h, dh, win = 1, 24, 2, 8, 6
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    out = flash_attention(q, k, v, causal=True, window=win, scale=1.0, kv_chunk=8)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (qpos >= kpos) & (kpos > qpos - win)
    sc = jnp.where(mask, sc, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_xl_memory_changes_logits():
    """Segment memory must actually inform predictions (paper architecture)."""
    from repro.models.stack import init_mems
    cfg = reduced("wt103-47m-dense") if False else None
    base = get_config("wt103-47m-dense")
    cfg = base.override(n_layers=2, d_model=64, vocab_size=128, xl_memory=8,
                        attention=base.attention.__class__(
                            n_heads=4, n_kv_heads=4, head_dim=16, kind="xl_rel"))
    from repro.configs.base import FFNConfig
    cfg = cfg.with_ffn(FFNConfig(kind="dense", d_ff=128, activation="relu"))
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    mems0 = init_mems(cfg, 2, jnp.bfloat16)
    h0, _, mems1 = m.forward(p, toks, mems=mems0)
    # replay with the produced (non-zero) memory: different context -> different h
    h1, _, _ = m.forward(p, toks, mems=mems1)
    assert float(jnp.abs(h0.astype(jnp.float32) -
                         h1.astype(jnp.float32)).max()) > 1e-4


def test_vocab_padding_masked():
    cfg = reduced("whisper-tiny").override(vocab_size=100)  # pads to 512
    m = build_model(cfg)
    assert m.vocab_padded == 512
    p = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, s=16)
    batch["tokens"] = batch["tokens"] % 100
    lg, _ = m.prefill(p, batch, m.init_cache(2, 16))
    assert np.asarray(lg[:, 100:]).max() < -1e20    # padded columns masked
