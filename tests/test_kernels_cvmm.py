"""Per-kernel validation: CVMM Pallas kernel (interpret mode) against the pure-jnp
oracle — shape/dtype sweeps, empty groups, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # (M, K, N, E, group_sizes)
    (64, 32, 48, 4, [16, 16, 16, 16]),
    (100, 36, 52, 5, [10, 0, 37, 30, 23]),      # uneven + empty group
    (7, 3, 5, 2, [7, 0]),                       # tiny, under one tile
    (300, 200, 80, 3, [0, 0, 300]),             # leading empty groups
    (256, 128, 128, 1, [256]),                  # single expert == plain matmul
    (130, 64, 64, 8, [130, 0, 0, 0, 0, 0, 0, 0]),
]


def _mk(m, k, n, e, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + k))
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    w = (0.1 * jax.random.normal(kw, (e, k, n), jnp.float32)).astype(dtype)
    return x, w


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cvmm_forward_matches_oracle(case, dtype):
    m, k, n, e, gs = case
    x, w = _mk(m, k, n, e, dtype)
    gs = jnp.array(gs)
    want = ref.cvmm_ref(x, gs, w)
    got = ops.cvmm(x, gs, w, impl="pallas_interpret")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", CASES)
def test_cvmm_ragged_matches_oracle(case):
    m, k, n, e, gs = case
    x, w = _mk(m, k, n, e, jnp.float32)
    gs = jnp.array(gs)
    np.testing.assert_allclose(np.asarray(ops.cvmm(x, gs, w, impl="ragged")),
                               np.asarray(ref.cvmm_ref(x, gs, w)),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("case", CASES[:4])
def test_cvmm_gradients_match(case):
    m, k, n, e, gs = case
    x, w = _mk(m, k, n, e, jnp.float32)
    gs = jnp.array(gs)

    def loss(impl):
        def f(x, w):
            y = ops.cvmm(x, gs, w, impl=impl)
            return jnp.sum(y * jnp.cos(jnp.arange(y.size).reshape(y.shape)))
        return jax.grad(f, argnums=(0, 1))(x, w)

    gx_r, gw_r = loss("ragged")
    gx_p, gw_p = loss("pallas_interpret")
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r),
                               atol=1e-4, rtol=1e-4)


def test_cvmm_dw_empty_group_zero():
    m, k, n, e = 64, 32, 16, 4
    x, w = _mk(m, k, n, e, jnp.float32)
    gs = jnp.array([32, 0, 32, 0])
    gw = jax.grad(lambda w: ops.cvmm(x, gs, w, impl="pallas_interpret").sum(),
                  )(w)
    assert np.all(np.asarray(gw[1]) == 0)
    assert np.all(np.asarray(gw[3]) == 0)
    assert np.any(np.asarray(gw[0]) != 0)


def test_cvmm_jit_compatible():
    m, k, n, e = 64, 32, 16, 4
    x, w = _mk(m, k, n, e, jnp.float32)
    gs = jnp.array([10, 20, 30, 4])
    f = jax.jit(lambda x, gs, w: ops.cvmm(x, gs, w, impl="pallas_interpret"))
    np.testing.assert_allclose(np.asarray(f(x, gs, w)),
                               np.asarray(ref.cvmm_ref(x, gs, w)),
                               atol=1e-5, rtol=1e-5)
