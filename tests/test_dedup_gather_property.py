"""Property suite for the deduplicated/sorted gather plan (ops.DedupGatherPlan
+ ops.gathered_weighted_sum_dedup) — the coalescing strategy behind
million-value PKM aggregation.

Covers the PR-7 contract:
  - plan layout invariants: row_src is the ascending unique set with sentinel
    tail, sel_pos/tok_src/weights index-indirect every flat (token, slot)
    selection back to its compacted slot, and the chunk table covers the
    valid prefix exactly (histogram mass == unique rows, descriptor count ==
    run_batched telemetry).
  - a numpy replay of the full execution: chunk-table gather of the compacted
    block, then the scatter-side indirection (expand by sel_pos, weight,
    scatter-add by tok_src) reproduces the einsum reference.
  - fwd + bwd parity vs the dense ``impl="dense"`` oracle semantics across
    duplicate-heavy selections, the all-unique worst case, and bf16.

``hypothesis`` is an OPTIONAL dev dependency (requirements-dev.txt): the
property tests are skipped when it is missing, and deterministic sweeps cover
the same cases either way."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # module-level importorskip would hide the tests below
    HAVE_HYPOTHESIS = False

from repro.kernels import cvmm, ops


def _mk_selection(n, s, r, seed, duplicate_heavy=False, all_unique=False):
    """A (n, s) selection over r rows: duplicate_heavy concentrates on a hot
    set of <= 8 rows (shared across tokens), all_unique makes every selection
    a distinct row (requires n*s <= r)."""
    rng = np.random.RandomState(seed)
    if all_unique:
        assert n * s <= r
        idx = rng.choice(r, size=n * s, replace=False).reshape(n, s)
    elif duplicate_heavy:
        hot = rng.choice(r, size=min(8, r), replace=False)
        idx = hot[rng.randint(0, len(hot), size=(n, s))]
    else:
        idx = rng.randint(0, r, size=(n, s))
    w = rng.randn(n, s).astype(np.float32)
    return jnp.asarray(idx.astype(np.int32)), jnp.asarray(w)


def _dense_oracle(values, idx, w):
    """The impl="dense" semantics: full (N, S, d) take + einsum, in f32."""
    rows = jnp.take(values, idx, axis=0).astype(jnp.float32)
    return jnp.einsum("ns,nsd->nd", w.astype(jnp.float32), rows)


# ---------------------------------------------------------------------------
# Plan layout + numpy replay of the compacted scatter indirection
# ---------------------------------------------------------------------------

def _check_plan_invariants(idx, w, r):
    n, s = idx.shape
    m = n * s
    plan = ops.make_dedup_gather_plan(idx, w, r)
    row_src = np.asarray(plan.row_src)
    sel_pos = np.asarray(plan.sel_pos)
    tok_src = np.asarray(plan.tok_src)
    weights = np.asarray(plan.weights)
    flat = np.asarray(idx).reshape(-1)

    # row_src: ascending unique prefix, sentinel tail, TM-padded
    assert plan.u_pad % ops.TM == 0
    uniq = np.unique(flat)
    nu = len(uniq)
    np.testing.assert_array_equal(row_src[:nu], uniq)
    assert (row_src[nu:] == r).all()
    # indirection: every flat selection maps back to its own row id / token
    assert sel_pos.shape == tok_src.shape == weights.shape == (m,)
    np.testing.assert_array_equal(row_src[sel_pos], flat)
    np.testing.assert_array_equal(tok_src, np.repeat(np.arange(n), s))
    np.testing.assert_allclose(weights, np.asarray(w).reshape(-1), rtol=1e-6)
    return plan, nu


def _replay_chunks(plan, r, values):
    """Numpy re-execution of the chunk table the way the kernel walks it (one
    loop per static size class over run_off boundaries): returns the gathered
    compacted block and the descriptor count."""
    rs = np.asarray(plan.row_src)
    rst = np.asarray(plan.run_start)
    rl = np.asarray(plan.run_len)
    nc = len(cvmm._RUN_SIZES)
    ro = np.asarray(plan.run_off).reshape(-1, nc + 1)
    out = np.zeros((plan.u_pad, values.shape[1]), np.float32)
    n_dma = 0
    for t in range(plan.u_pad // ops.TM):
        for ci, sz in enumerate(cvmm._RUN_SIZES):
            for j in range(ro[t, ci], ro[t, ci + 1]):
                assert int(rl[t * ops.TM + j]) == sz
                off = int(rst[t * ops.TM + j])
                src = int(rs[t * ops.TM + off])
                assert src + sz <= r, "chunk overruns the value table"
                out[t * ops.TM + off: t * ops.TM + off + sz] = \
                    values[src: src + sz]
                n_dma += 1
    return out, n_dma


def _check_replay(idx, w, r, d=16, seed=0):
    """End-to-end numpy replay: chunk-table gather -> sel_pos expansion ->
    weight -> tok_src scatter-add == the einsum reference."""
    n, s = idx.shape
    plan, nu = _check_plan_invariants(idx, w, r)
    values = np.random.RandomState(seed).randn(r, d).astype(np.float32)
    block, n_dma = _replay_chunks(plan, r, values)
    # compacted scatter indirection, in numpy
    sel_rows = block[np.asarray(plan.sel_pos)]              # (M, d)
    wrows = sel_rows * np.asarray(plan.weights)[:, None]
    got = np.zeros((n, d), np.float32)
    np.add.at(got, np.asarray(plan.tok_src), wrows)
    want = np.asarray(_dense_oracle(jnp.asarray(values), idx, w))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    # telemetry invariants: descriptor count matches, histogram mass covers
    # every unique row exactly once, dedup never exceeds one-per-selection
    stats = ops.plan_dma_stats(plan, r)
    assert stats["run_batched"] == n_dma
    assert stats["unique_rows"] == nu
    assert stats["per_row"] == n * s
    hist = stats["chunk_hist"]
    assert sum(hist.values()) == n_dma
    assert sum(int(sz) * c for sz, c in hist.items()) == nu
    assert 0 < n_dma <= nu <= n * s
    return stats


def test_dedup_plan_duplicate_heavy_replay():
    """Hot-set selections: dedup collapses shared rows, so the descriptor
    count is bounded by the hot-set size, not the selection count."""
    idx, w = _mk_selection(64, 8, 1000, seed=0, duplicate_heavy=True)
    stats = _check_replay(idx, w, 1000)
    assert stats["unique_rows"] <= 8
    assert stats["batching_factor"] >= 64.0    # 512 selections, <= 8 DMAs


def test_dedup_plan_all_unique_worst_case():
    """No sharing at all: dedup buys nothing, but the plan must still be
    exact and never issue MORE descriptors than one per selection."""
    idx, w = _mk_selection(16, 4, 4096, seed=1, all_unique=True)
    stats = _check_replay(idx, w, 4096)
    assert stats["unique_rows"] == 64
    assert stats["run_batched"] <= 64


def test_dedup_plan_adjacent_rows_coalesce():
    """Adjacent value indices form real contiguous runs: a selection covering
    one dense 128-row block is a single size-128 descriptor."""
    idx = jnp.arange(128, dtype=jnp.int32).reshape(16, 8) + 100
    w = jnp.ones((16, 8), jnp.float32)
    stats = _check_replay(idx, w, 1 << 20)
    assert stats["chunk_hist"]["128"] == 1
    assert stats["run_batched"] == 1
    assert stats["batching_factor"] == 128.0


# ---------------------------------------------------------------------------
# fwd + bwd parity vs the dense oracle (kernel execution, interpret mode)
# ---------------------------------------------------------------------------

def _check_parity(idx, w, r, d, dtype, seed=2):
    n = idx.shape[0]
    values = jax.random.normal(jax.random.PRNGKey(seed), (r, d),
                               jnp.float32).astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2

    def planned(values, w):
        plan = ops.make_dedup_gather_plan(idx, w, r)
        return ops.gathered_weighted_sum_dedup(values, plan, n, interpret=True)

    got = planned(values, w)
    want = _dense_oracle(values, idx, w)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol, rtol=tol)

    probe = lambda y: jnp.sum(y.astype(jnp.float32) *
                              jnp.cos(jnp.arange(y.size).reshape(y.shape)))
    gv, gw = jax.grad(lambda v, w: probe(planned(v, w)), (0, 1))(values, w)
    rv, rw = jax.grad(lambda v, w: probe(_dense_oracle(v, idx, w)),
                      (0, 1))(values, w)
    gtol = 1e-4 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(np.asarray(gv, np.float32),
                               np.asarray(rv, np.float32),
                               atol=gtol, rtol=gtol)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               atol=gtol, rtol=gtol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", ["duplicate_heavy", "all_unique", "mixed"])
def test_dedup_gws_parity_sweep(dtype, shape):
    """Deterministic sweep (no hypothesis needed): fwd+bwd == dense oracle
    across sharing regimes and dtypes."""
    idx, w = _mk_selection(24, 4, 256, seed=3,
                           duplicate_heavy=shape == "duplicate_heavy",
                           all_unique=shape == "all_unique")
    _check_parity(idx, w, 256, 24, dtype)


def test_dedup_gws_single_token_and_constant_row():
    """Edge cases: one token, and every slot selecting the SAME row (maximal
    collision on the compacted backward scatter)."""
    idx = jnp.full((8, 4), 7, jnp.int32)
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    _check_parity(idx, w, 32, 16, jnp.float32)
    idx1 = jnp.asarray([[3, 9, 9, 0]], jnp.int32)
    w1 = jnp.asarray([[1.0, -2.0, 0.5, 3.0]], jnp.float32)
    _check_parity(idx1, w1, 16, 16, jnp.float32)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 32), st.integers(1, 6), st.integers(4, 300),
           st.integers(0, 2 ** 31 - 1), st.booleans())
    def test_dedup_plan_replay_property(n, s, r, seed, duplicate_heavy):
        """Hypothesis: plan invariants + numpy replay == reference for random
        selection shapes, duplicate-heavy or uniform."""
        idx, w = _mk_selection(n, s, r, seed=seed % (2 ** 31 - 1),
                               duplicate_heavy=duplicate_heavy and r >= 8)
        _check_replay(idx, w, r, d=8, seed=seed % 1000)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_dedup_plan_replay_property():
        pass
