"""Substrate tests: optimizer, schedules, data determinism, checkpoint atomicity +
resharding, gradient compression, chunked CE."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import OptimizerConfig
from repro.data import DataIterator, make_dataset
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_grads, init_compression_state, make_schedule)
from repro.runtime.loss import chunked_cross_entropy


def test_adamw_decreases_quadratic():
    cfg = OptimizerConfig(lr=0.1, schedule="constant", grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt = adamw_update(g, opt, params, cfg, jnp.float32(0.05))
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedules():
    for kind in ("cosine", "wsd", "constant"):
        cfg = OptimizerConfig(lr=1e-3, schedule=kind, warmup_steps=10,
                              total_steps=100)
        s = make_schedule(cfg)
        assert float(s(jnp.int32(0))) == 0.0 or kind == "constant"
        assert abs(float(s(jnp.int32(10))) - 1e-3) < 1e-9
        if kind == "cosine":
            assert float(s(jnp.int32(100))) < 1e-5
        if kind == "wsd":
            assert abs(float(s(jnp.int32(50))) - 1e-3) < 1e-9   # stable phase
            assert float(s(jnp.int32(100))) < 1e-4              # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_grad_compression_error_feedback():
    """Error feedback: sum of decompressed grads converges to sum of true grads."""
    g_true = jnp.array([1e-3, 2.5e-4, -3.33e-4, 0.1])
    err = init_compression_state({"g": g_true})
    total = jnp.zeros(4)
    for i in range(50):
        wire, err = compress_grads({"g": g_true}, err, "int8")
        total = total + wire["g"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g_true) * 50,
                               rtol=0.02, atol=1e-4)


def test_data_determinism_and_resume():
    ds = make_dataset("synthetic", 256)
    a = DataIterator(ds, 8, 32, seed=1)
    b = DataIterator(ds, 8, 32, seed=1)
    for _ in range(3):
        a.next()
    state = a.state()
    b.restore(state)
    np.testing.assert_array_equal(a.next()["tokens"], b.next()["tokens"])


def test_data_host_sharding_partitions_global_batch():
    ds = make_dataset("synthetic", 256)
    full = DataIterator(ds, 8, 16, seed=2)
    h0 = DataIterator(ds, 8, 16, seed=2, host_index=0, host_count=2)
    h1 = DataIterator(ds, 8, 16, seed=2, host_index=1, host_count=2)
    f = full.next()["tokens"]
    np.testing.assert_array_equal(f[:4], h0.next()["tokens"])
    np.testing.assert_array_equal(f[4:], h1.next()["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"data": {"step": step}})
    assert mgr.all_steps() == [2, 3]               # keep=2 garbage-collected
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = mgr.restore(like)
    assert extra["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity(tmp_path):
    """A torn tmp dir (crash mid-save) is never visible as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(5, {"x": jnp.ones(3)})
    os.makedirs(tmp_path / "tmp.6.999", exist_ok=True)      # simulated torn write
    (tmp_path / "tmp.6.999" / "meta.json").write_text("{corrupt")
    assert mgr.latest_step() == 5


def test_checkpoint_reshard_restore(tmp_path):
    """Elastic restore: save unsharded, restore with a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


@pytest.mark.parametrize("chunks", [1, 4])
def test_chunked_ce_matches_dense(chunks):
    b, s, d, v = 2, 9, 16, 50
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v)) * 0.1
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    dense, _ = chunked_cross_entropy(h, w, labels, chunks=1)
    ck, _ = chunked_cross_entropy(h, w, labels, chunks=chunks)
    np.testing.assert_allclose(float(dense), float(ck), rtol=1e-5)
    # grads too
    gd = jax.grad(lambda h: chunked_cross_entropy(h, w, labels, chunks=1)[0])(h)
    gc = jax.grad(lambda h: chunked_cross_entropy(h, w, labels, chunks=chunks)[0])(h)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gc), atol=1e-5)


def test_chunked_ce_vocab_mask():
    b, s, d, v = 1, 4, 8, 32
    h = jax.random.normal(jax.random.PRNGKey(0), (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.1
    labels = jnp.zeros((b, s), jnp.int32)
    full, _ = chunked_cross_entropy(h, w, labels)
    masked, _ = chunked_cross_entropy(h, w, labels, n_valid_vocab=16)
    # masking vocab reduces the partition function -> lower or equal CE
    assert float(masked) <= float(full) + 1e-6
