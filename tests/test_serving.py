"""Serving stack: paged KV cache, continuous-batching engine, decode plans.

The oracle strategy everywhere: the paged/cached path must reproduce the
contiguous-cache greedy decode EXACTLY (same argmax tokens, same logits up
to dtype noise) — serving optimizations are layout changes, not numerics
changes.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced
from repro.core import dispatch
from repro.models.lm import LM
from repro.serving import (DecodePlanCache, Engine, PagedKVCache, Request,
                           capture_sizes, make_provider, pick_capture)


@pytest.fixture(scope="module")
def dense_lm():
    cfg = reduced("llama3-8b")
    lm = LM(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_lm():
    cfg = reduced("granite-moe-3b-a800m")   # sort dispatch, GLU experts, k=2
    lm = LM(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def naive_greedy(lm, params, prompt, max_new, eos=-1, max_len=96):
    """Contiguous-cache greedy reference (the pre-engine decode loop)."""
    cache = lm.init_cache(1, max_len)
    lg, cache = lm.prefill(params, {"tokens": jnp.asarray([prompt],
                                                          jnp.int32)}, cache)
    out = [int(np.argmax(np.asarray(lg)[0]))]
    pos = len(prompt)
    while len(out) < max_new and out[-1] != eos:
        lg, cache = lm.decode_step(params, cache,
                                   jnp.asarray([out[-1]], jnp.int32),
                                   jnp.int32(pos))
        out.append(int(np.argmax(np.asarray(lg)[0])))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# Paged KV allocator
# ---------------------------------------------------------------------------

def test_paged_kv_alloc_free_reuse():
    kv = PagedKVCache(n_pages=8, page_size=4)
    assert kv.free_pages == 7                 # page 0 reserved
    assert kv.pages_needed(9) == 3
    a = kv.alloc("a", 9)
    assert len(a) == 3 and 0 not in a
    assert kv.free_pages == 4
    with pytest.raises(KeyError):
        kv.alloc("a", 4)                      # double alloc
    assert not kv.can_alloc(17)               # needs 5 > 4 free
    with pytest.raises(MemoryError):
        kv.alloc("b", 17)
    b = kv.alloc("b", 16)
    assert kv.free_pages == 0 and not set(a) & set(b)
    kv.free("a")
    assert kv.free_pages == 3
    # LIFO reuse: freshly freed pages come back first, in order
    assert kv.alloc("c", 12) == a
    t = kv.block_table("c", 6)
    assert t.shape == (6,) and list(t[:3]) == a and list(t[3:]) == [0, 0, 0]
    with pytest.raises(ValueError):
        kv.block_table("c", 2)                # table narrower than allocation


def test_capture_sizes():
    assert capture_sizes(8) == (1, 2, 4, 8)
    assert capture_sizes(6) == (1, 2, 4, 6)
    assert capture_sizes(1) == (1,)
    assert pick_capture(3, (1, 2, 4, 8)) == 4
    assert pick_capture(8, (1, 2, 4, 8)) == 8
    with pytest.raises(ValueError):
        pick_capture(9, (1, 2, 4, 8))


# ---------------------------------------------------------------------------
# Paged attention vs the contiguous-cache oracle
# ---------------------------------------------------------------------------

def test_paged_cache_matches_contiguous_oracle(dense_lm):
    """Shuffled page tables + chunked prefill + batched decode must produce
    the same logits as the contiguous cache at every step."""
    lm, params = dense_lm
    cfg = lm.cfg
    B, PROMPT, NEW, PS, CHUNK = 3, 13, 5, 8, 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size, size=(B, PROMPT)).astype(np.int32)

    cache = lm.init_cache(B, 64)
    lg, cache = lm.prefill(params, {"tokens": jnp.asarray(prompts)}, cache)
    oracle = [np.asarray(lg)]
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for i in range(NEW - 1):
        lg, cache = lm.decode_step(params, cache, tok, jnp.int32(PROMPT + i))
        oracle.append(np.asarray(lg))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)

    n_blocks = -(-(PROMPT + NEW) // PS)
    n_pages = 1 + B * n_blocks
    pcache = lm.init_paged_cache(n_pages, PS)
    free = list(range(1, n_pages))
    rng.shuffle(free)                          # non-contiguous physical pages
    tables = np.array([[free.pop() for _ in range(n_blocks)]
                       for _ in range(B)], np.int32)

    first = []
    for bi in range(B):
        bt = jnp.asarray(tables[bi:bi + 1])
        start = 0
        while start < PROMPT:
            ln = min(CHUNK, PROMPT - start)
            chunk = np.zeros((1, CHUNK), np.int32)
            chunk[0, :ln] = prompts[bi, start:start + ln]
            lg, pcache = lm.prefill_paged(params, jnp.asarray(chunk), pcache,
                                          bt, jnp.int32(start), jnp.int32(ln))
            start += ln
        first.append(np.asarray(lg)[0])
    np.testing.assert_allclose(np.stack(first), oracle[0], atol=1e-4)

    tok = jnp.argmax(jnp.asarray(np.stack(first)), -1).astype(jnp.int32)
    pos = jnp.full((B,), PROMPT, jnp.int32)
    for i in range(NEW - 1):
        lg, pcache = lm.decode_step_paged(params, pcache, tok, pos,
                                          jnp.asarray(tables))
        np.testing.assert_allclose(np.asarray(lg), oracle[i + 1], atol=1e-4)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        pos = pos + 1


def test_paged_guard_rejects_learned_pe():
    lm = LM(reduced("wt103-47m-dense"))       # learned positional embeddings
    if lm.cfg.pos_encoding in ("rope", "none"):
        pytest.skip("arch no longer uses learned PE")
    with pytest.raises(NotImplementedError):
        lm.init_paged_cache(4, 8)


# ---------------------------------------------------------------------------
# Engine: continuous batching over the MoE config (decode plans active)
# ---------------------------------------------------------------------------

def test_engine_matches_naive_greedy(moe_lm):
    """Mixed prompt lengths and budgets: lanes join and retire mid-flight,
    and every request's tokens must equal the single-request reference."""
    lm, params = moe_lm
    rng = np.random.default_rng(1)
    reqs, refs = [], {}
    for i in range(4):
        prompt = rng.integers(1, lm.cfg.vocab_size,
                              size=int(rng.integers(3, 18))).tolist()
        max_new = int(rng.integers(2, 10))
        reqs.append(Request(rid=i, prompt=prompt, max_new=max_new))
        refs[i] = naive_greedy(lm, params, prompt, max_new)
    eng = Engine(lm, params, max_batch=3, max_len=64, page_size=8,
                 burst_steps=4, prefill_chunk=8)
    try:
        outs = eng.run(reqs)
    finally:
        eng.close()
    assert outs == refs
    assert eng.stats["completed"] == 4
    assert not eng.kv._owned                  # every page returned


def test_engine_eos_at_step_zero(moe_lm):
    """A request whose very first greedy token is its EOS completes with one
    token and never joins the decode batch."""
    lm, params = moe_lm
    prompt = [5, 9, 2, 14]
    t0 = naive_greedy(lm, params, prompt, 4)[0]
    reqs = [Request(rid="eos0", prompt=prompt, max_new=8, eos=t0),
            Request(rid="bg", prompt=[3, 1, 7], max_new=3)]
    eng = Engine(lm, params, max_batch=2, max_len=64, page_size=8,
                 burst_steps=2, prefill_chunk=8, use_decode_plans=False)
    try:
        outs = eng.run(reqs)
    finally:
        eng.close()
    assert outs["eos0"] == [t0]
    assert len(outs["bg"]) == 3
    assert not eng.kv._owned


def test_engine_admission_backpressure(moe_lm):
    """More requests than lanes AND pages: admission waits for retirements
    (never raises, never drops), and everything still completes correctly."""
    lm, params = moe_lm
    rng = np.random.default_rng(2)
    reqs, refs = [], {}
    for i in range(5):
        prompt = rng.integers(1, lm.cfg.vocab_size, size=5).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new=4))
        refs[i] = naive_greedy(lm, params, prompt, 4)
    # 2 lanes; pages for ~2 requests in flight (plus the reserved page 0)
    eng = Engine(lm, params, max_batch=2, max_len=16, page_size=8,
                 n_pages=5, burst_steps=2, prefill_chunk=8,
                 use_decode_plans=False)
    try:
        outs = eng.run(reqs)
    finally:
        eng.close()
    assert outs == refs
    assert eng.kv.free_pages == 4 and not eng.kv._owned


def test_engine_cancel_evicts_mid_flight(moe_lm):
    lm, params = moe_lm
    eng = Engine(lm, params, max_batch=2, max_len=64, page_size=8,
                 burst_steps=2, prefill_chunk=8, use_decode_plans=False)
    try:
        eng.submit(Request(rid="keep", prompt=[2, 4, 6], max_new=6))
        eng.submit(Request(rid="evict", prompt=[1, 3, 5], max_new=6))
        while eng.sched or eng._partial is not None:
            eng.step()                        # admit both, maybe some decode
        assert eng.cancel("evict")
        assert not eng.cancel("evict")        # already gone
        while eng.has_work():
            eng.step()
    finally:
        eng.close()
    assert len(eng.outputs["keep"]) == 6
    assert len(eng.outputs["evict"]) < 6      # partial output preserved
    assert eng.stats["evicted"] == 1 and not eng.kv._owned


# ---------------------------------------------------------------------------
# Decode-plan cache: spy counters and provider parity
# ---------------------------------------------------------------------------

def test_plan_cache_skeleton_spy_counters():
    cache = DecodePlanCache()
    p1 = cache.skeleton(4, 2, 4, 64, 32, jnp.float32)
    assert p1 is not None
    assert cache.counters() == {"rebuilds": 1, "hits": 0, "assembles": 0,
                                "assembled_hits": 0}
    p2 = cache.skeleton(4, 2, 4, 64, 32, jnp.float32)   # stable shape: hit
    assert p2 is p1
    assert cache.rebuilds == 1 and cache.hits == 1
    cache.skeleton(8, 2, 4, 64, 32, jnp.float32)        # new shape: rebuild
    assert cache.rebuilds == 2


def test_plan_cache_routing_invalidation():
    cache = DecodePlanCache()
    skel = cache.skeleton(4, 2, 4, 64, 32, jnp.float32)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 4, size=(4, 2)), jnp.int32)
    gates = jnp.asarray(rng.random((4, 2)), jnp.float32)
    a1 = cache.assembled(skel, idx, gates)
    assert cache.assembles == 1 and cache.assembled_hits == 0
    a2 = cache.assembled(skel, idx, gates)    # stable routing: zero rebuilds
    assert a2 is a1
    assert cache.assembles == 1 and cache.assembled_hits == 1
    idx2 = (idx + 1) % 4
    a3 = cache.assembled(skel, idx2, gates)   # routing change: new assembly
    assert a3 is not a1
    assert cache.assembles == 2


def test_decode_provider_parity(moe_lm):
    """Paged decode logits with the cached-plan provider installed must
    match the provider-free sort path."""
    lm, params = moe_lm
    cfg = lm.cfg
    B, PS = 2, 8
    rng = np.random.default_rng(3)
    prompts = rng.integers(1, cfg.vocab_size, size=(B, 6)).astype(np.int32)
    n_blocks = 2
    tables = np.arange(1, 1 + B * n_blocks,
                       dtype=np.int32).reshape(B, n_blocks)

    def one_step(use_provider):
        pcache = lm.init_paged_cache(1 + B * n_blocks, PS)
        cache_state = pcache
        for bi in range(B):
            _, cache_state = lm.prefill_paged(
                params, jnp.asarray(prompts[bi:bi + 1]), cache_state,
                jnp.asarray(tables[bi:bi + 1]), jnp.int32(0), jnp.int32(6))
        plan_cache = None
        if use_provider:
            plan_cache = DecodePlanCache()
            dispatch.set_decode_provider(
                make_provider(plan_cache, max_tokens=8))
        try:
            lg, _ = lm.decode_step_paged(
                params, cache_state, jnp.asarray([7, 11], jnp.int32),
                jnp.full((B,), 6, jnp.int32), jnp.asarray(tables))
        finally:
            dispatch.set_decode_provider(None)
        return np.asarray(lg), plan_cache

    ref, _ = one_step(False)
    got, plan_cache = one_step(True)
    assert plan_cache.rebuilds >= 1           # the provider actually served
    # the model runs in bfloat16: the cached-plan path rounds its grouped
    # GEMMs independently of the sort path, so compare at bf16 tolerance
    # and require identical greedy decisions
    np.testing.assert_allclose(got, ref, rtol=3e-2, atol=3e-2)
    np.testing.assert_array_equal(np.argmax(got, -1), np.argmax(ref, -1))
