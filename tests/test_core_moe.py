"""sigma-MoE and baselines: routing, dispatch-path equivalence, regularizers,
initialization, expert dropout (paper Secs. 3.3-5)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import moe_ffn
from repro.core import (apply_moe, entropy_reg, init_moe, norm_topk,
                        select_experts, sinkhorn, usage_stats)
from repro.core.routing import SelectionInfo

D, NE, G, K = 32, 8, 16, 2


def _setup(dispatch="sort", **kw):
    cfg = moe_ffn(NE, G, K, dispatch=dispatch, **kw)
    p = init_moe(jax.random.PRNGKey(1), D, cfg, n_layers=4)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 10, D))
    return cfg, p, x


def test_sort_equals_einsum_without_drops():
    cfg_s, p, x = _setup("sort")
    cfg_e = dataclasses.replace(cfg_s, dispatch="einsum", capacity_factor=16.0)
    ys, _ = apply_moe(p, x, cfg_s)
    ye, _ = apply_moe(p, x, cfg_e)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ye), atol=1e-5, rtol=1e-5)


def test_moe_equals_dense_when_all_experts_selected():
    """K = N_E with gates forced to 1 must reproduce the dense MLP y = W2 relu(W1 x):
    the unified-view consistency check (paper Sec. 3)."""
    cfg, p, x = _setup("sort")
    cfg = dataclasses.replace(cfg, k=NE)
    # zero router -> sigmoid(0) = 0.5 for every expert -> y == 0.5 * dense MLP
    p = dict(p, router=jnp.zeros_like(p["router"]))
    y, _ = apply_moe(p, x, cfg)
    w1 = np.concatenate([np.asarray(p["we1"][e]) for e in range(NE)], axis=1)
    w2 = np.concatenate([np.asarray(p["we2"][e]) for e in range(NE)], axis=0)
    dense = np.maximum(np.asarray(x) @ w1, 0) @ w2
    np.testing.assert_allclose(2.0 * np.asarray(y), dense, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kind,act", [("sigma_moe", "sigmoid"),
                                      ("switch", "softmax"),
                                      ("noisy_topk", "softmax"),
                                      ("sbase", "sigmoid")])
@pytest.mark.parametrize("dispatch", ["sort", "einsum"])
def test_variants_forward_backward(kind, act, dispatch):
    cfg, p, x = _setup(dispatch, selector_activation=act, reg_kind="entropy",
                       reg_gamma=0.01)
    cfg = dataclasses.replace(cfg, kind=kind, expert_dropout=0.1)
    p = init_moe(jax.random.PRNGKey(1), D, cfg, n_layers=4)
    y, aux = apply_moe(p, x, cfg, rng=jax.random.PRNGKey(2), train=True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    g = jax.grad(lambda p: apply_moe(p, x, cfg, rng=jax.random.PRNGKey(2),
                                     train=True)[0].sum())(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_expert_dropout_masks_whole_experts():
    cfg, p, x = _setup("sort")
    cfg = dataclasses.replace(cfg, expert_dropout=0.9)
    # with delta=0.9 nearly all experts are dropped -> selected set shrinks
    infos = []
    xf = x.reshape(-1, D)
    logits = xf @ p["router"]
    i_train = select_experts(logits, dataclasses.replace(cfg, expert_dropout=0.9),
                             rng=jax.random.PRNGKey(3), train=True)
    i_eval = select_experts(logits, cfg, train=False)
    # eval ignores dropout: top-k gates strictly positive
    assert np.all(np.asarray(i_eval.gates) > 0)
    # train: dropped experts produce zero gates for at least some tokens
    assert np.asarray(i_train.gates).min() == 0.0


def test_sigma_init_matches_dense_std():
    cfg, p, _ = _setup("sort")
    import math
    s1 = math.sqrt(2.0 / (D * 4))
    s2 = math.sqrt(2.0 / (NE * G * 4))
    assert abs(np.asarray(p["we1"]).std() - s1) / s1 < 0.1
    assert abs(np.asarray(p["we2"]).std() - s2) / s2 < 0.1
    # router rows all have equal norm (footnote 5)
    norms = np.linalg.norm(np.asarray(p["router"]), axis=0)
    np.testing.assert_allclose(norms, norms[0], rtol=1e-5)


def test_standard_init_differs():
    cfg = moe_ffn(NE, G, K, sigma_moe_init=False)
    p = init_moe(jax.random.PRNGKey(1), D, cfg, n_layers=4)
    assert abs(np.asarray(p["we2"]).std() - (0.1 / G) ** 0.5) < 0.02


def test_entropy_reg_minimized_by_uniform():
    probs_uniform = jnp.full((64, NE), 1.0 / NE)
    probs_peaky = jnp.zeros((64, NE)).at[:, 0].set(1.0)
    mk = lambda pr: SelectionInfo(probs=pr, sel=pr,
                                  idx=jnp.zeros((64, K), jnp.int32),
                                  gates=jnp.ones((64, K)))
    assert entropy_reg(mk(probs_uniform), NE) < entropy_reg(mk(probs_peaky), NE)


def test_sinkhorn_balances_columns():
    logits = jax.random.normal(jax.random.PRNGKey(0), (128, NE)) * 3.0
    pi = sinkhorn(logits, 20)
    col = np.asarray(pi.sum(0))
    np.testing.assert_allclose(col, 128 / NE, rtol=0.05)
    row = np.asarray(pi.sum(1))
    np.testing.assert_allclose(row, 1.0, rtol=0.05)


def test_norm_topk_sums_to_one():
    s = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (32, NE)))
    gates, idx = norm_topk(s, K)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)


def test_padded_experts_never_selected():
    cfg = moe_ffn(6, G, K)               # 6 experts, pad to 8 (ep_degree=4 -> 8)
    p = init_moe(jax.random.PRNGKey(1), D, cfg, n_layers=2, ep_degree=4)
    assert p["we1"].shape[0] == 8
    x = jax.random.normal(jax.random.PRNGKey(0), (16, D))
    xf = x.reshape(-1, D)
    logits = xf @ p["router"]
    logits = jnp.concatenate([logits, jnp.full((16, 2), -1e9)], -1)
    info = select_experts(logits, cfg, train=False, n_valid_experts=6)
    assert np.asarray(info.idx).max() < 6


def test_capacity_drops_reported():
    cfg, p, x = _setup("einsum", capacity_factor=0.25)
    y, aux = apply_moe(p, x, cfg)
    assert float(aux["moe_dropped"]) > 0.0


def test_usage_stats_detects_collapse():
    idx_collapsed = jnp.zeros((128, K), jnp.int32)
    idx_uniform = jnp.stack([jnp.arange(128) % NE,
                             (jnp.arange(128) + 1) % NE], -1)
    gates = jnp.ones((128, K))
    probs = jnp.full((128, NE), 1.0 / NE)
    s_c = usage_stats(SelectionInfo(probs, probs, idx_collapsed, gates), NE)
    s_u = usage_stats(SelectionInfo(probs, probs, idx_uniform, gates), NE)
    assert float(s_c["usage_entropy"]) < float(s_u["usage_entropy"])


@pytest.mark.parametrize("glu", [False, True])
def test_shard_map_parity_and_no_dummy_glu_weight(glu, monkeypatch):
    """shard_map EP path == einsum path for GLU on AND off, on a real (single
    device) 'model' mesh so the shard_map branch actually runs (it lives in
    core/dispatch.py — the shared execution layer — since the PR 5 refactor).
    Guards the dummy-w1g fix: the non-GLU path must ship exactly 5 operands
    through shard_map (no (E,1,1) zeros placeholder, no size-1-broadcast
    einsum)."""
    from repro.core import dispatch as dispatch_mod
    from repro.sharding import mesh_context

    cfg_e = moe_ffn(NE, G, K, dispatch="einsum", capacity_factor=8.0)
    cfg_e = dataclasses.replace(cfg_e, glu_experts=glu)
    cfg_s = dataclasses.replace(cfg_e, dispatch="shard_map")
    p = init_moe(jax.random.PRNGKey(1), D, cfg_e, n_layers=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, D))

    shipped = {}
    orig = dispatch_mod._shard_map

    def spy(fn, **kw):
        inner = orig(fn, **kw)

        def call(*args):
            shipped["n_operands"] = len(args)
            return inner(*args)
        return call

    monkeypatch.setattr(dispatch_mod, "_shard_map", spy)
    mesh = jax.make_mesh((1,), ("model",))
    with mesh_context(mesh):
        ye, _ = apply_moe(p, x, cfg_e)
        ys, _ = apply_moe(p, x, cfg_s)
        gs = jax.grad(lambda p: apply_moe(p, x, cfg_s)[0].sum())(p)
        ge = jax.grad(lambda p: apply_moe(p, x, cfg_e)[0].sum())(p)
    assert shipped["n_operands"] == (6 if glu else 5)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys), atol=1e-5)
    for name in ge:
        np.testing.assert_allclose(np.asarray(ge[name]), np.asarray(gs[name]),
                                   atol=1e-4, err_msg=name)


def test_sort_dispatch_falls_back_to_ragged_when_no_tile_fits(monkeypatch):
    """_pick_tn returning None must not crash the sort path: when even the
    UNFUSED pallas kernels cannot tile the working set into VMEM,
    dispatch._sort_path falls back to XLA's ragged grouped matmul instead of
    raising at trace time (and stays numerically identical to an explicit
    ragged run)."""
    from repro.kernels import cvmm, ops as kops

    cfg, p, x = _setup("sort")
    # d=32 -> k_pad=128: tn=128 needs > 128KiB; starve it so nothing fits.
    monkeypatch.setattr(cvmm, "VMEM_BUDGET", 1 << 16)
    assert not kops.pallas_supported(D, cfg.expert_size)
    assert not kops.fused_supported(40, D, cfg.expert_size, cfg.activation)
    kops.set_default_impl("pallas_fused_interpret")
    try:
        y, _ = apply_moe(p, x, cfg)
        gy = jax.grad(lambda p: apply_moe(p, x, cfg)[0].sum())(p)
    finally:
        kops.set_default_impl(None)
    kops.set_default_impl("ragged")
    try:
        yr, _ = apply_moe(p, x, cfg)
        gr = jax.grad(lambda p: apply_moe(p, x, cfg)[0].sum())(p)
    finally:
        kops.set_default_impl(None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-6)
    for name in gr:
        np.testing.assert_allclose(np.asarray(gy[name]), np.asarray(gr[name]),
                                   atol=1e-5, err_msg=name)
