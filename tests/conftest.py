import os

# Tests see the single real CPU device (the dry-run sets its own flags in a
# subprocess). Keep allocations small and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
