"""Fused CVMM pipeline validation: the ``CvmmPlan`` layout object and the
gather->grouped-GEMM->epilogue kernels (interpret mode on CPU) against the
``ragged`` / pure-jnp oracles — forward, gradients, empty experts, E-padding,
and the plan-reuse regression (backward must not re-derive the layout)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cvmm, ops

# (N_tokens, d_model, E, expert_size G, K, n_valid_experts)
# n_valid < E models EP-padding: experts >= n_valid are never routed to.
CASES = [
    (40, 24, 5, 16, 2, 5),
    (64, 32, 4, 32, 2, 3),        # padded expert (idx never reaches expert 3)
    (9, 8, 3, 8, 1, 2),           # tiny, under one tile, K=1
    (150, 48, 6, 24, 4, 6),
    (32, 16, 2, 16, 2, 1),        # all tokens on one expert, one empty
]


def _mk(case, dtype, seed=0):
    n, d, e, g, k, e_valid = case
    key = jax.random.PRNGKey(seed + n * 13 + d)
    kx, ki, kg, k1, k2, k3 = jax.random.split(key, 6)
    xf = jax.random.normal(kx, (n, d), jnp.float32).astype(dtype)
    idx = jax.random.randint(ki, (n, k), 0, e_valid)
    gates = jax.nn.softmax(jax.random.normal(kg, (n, k), jnp.float32), -1)
    w1 = (0.3 * jax.random.normal(k1, (e, d, g), jnp.float32)).astype(dtype)
    w1g = (0.3 * jax.random.normal(k2, (e, d, g), jnp.float32)).astype(dtype)
    w2 = (0.3 * jax.random.normal(k3, (e, g, d), jnp.float32)).astype(dtype)
    return xf, idx, gates, w1, w1g, w2


def _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, act):
    """Unfused reference on the ragged-dot backend (differentiable)."""
    n, k = idx.shape
    e_flat = idx.reshape(-1)
    g_flat = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), k)
    perm = jnp.argsort(e_flat, stable=True)
    gs = jnp.bincount(e_flat, length=e).astype(jnp.int32)
    xs = xf[tok[perm]]
    h = jax.lax.ragged_dot(xs, w1.astype(xs.dtype), gs)
    u = act(h)
    if w1g is not None:
        u = u * jax.lax.ragged_dot(xs, w1g.astype(xs.dtype), gs)
    y = jax.lax.ragged_dot(u, w2.astype(u.dtype), gs)
    y = y * g_flat[perm][:, None].astype(y.dtype)
    return jnp.zeros_like(xf).at[tok[perm]].add(y)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("glu", [False, True])
def test_fused_forward_matches_ragged(case, dtype, glu):
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, dtype)
    if not glu:
        w1g = None
    plan = ops.make_moe_plan(idx, gates, n, e)
    got = ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                            interpret=True)
    want = _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", CASES[:3])
@pytest.mark.parametrize("glu", [False, True])
def test_fused_gradients_match_ragged(case, glu):
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)
    if not glu:
        w1g = None
    act = lambda x: jax.nn.gelu(x, approximate=True)
    probe = lambda y: jnp.sum(y * jnp.cos(jnp.arange(y.size).reshape(y.shape)))

    def loss_fused(xf, gates, w1, w1g, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        return probe(ops.moe_mlp_fused(xf, plan, w1, w2, w1g,
                                       activation="gelu", interpret=True))

    def loss_ref(xf, gates, w1, w1g, w2):
        return probe(_oracle_mlp(xf, idx, gates, w1, w1g, w2, e, act))

    if glu:
        gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3, 4))(xf, gates, w1, w1g, w2)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(xf, gates, w1, w1g, w2)
        names = ("dx", "dgates", "dw1", "dw1g", "dw2")
    else:
        f2 = lambda fn: (lambda xf, gates, w1, w2: fn(xf, gates, w1, None, w2))
        gf = jax.grad(f2(loss_fused), argnums=(0, 1, 2, 3))(xf, gates, w1, w2)
        gr = jax.grad(f2(loss_ref), argnums=(0, 1, 2, 3))(xf, gates, w1, w2)
        names = ("dx", "dgates", "dw1", "dw2")
    for name, a, b in zip(names, gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


def test_fused_empty_expert_weight_grads_zero():
    """Experts that receive no rows must get exactly-zero weight gradients."""
    case = (64, 32, 4, 32, 2, 3)          # expert 3 never selected
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)

    def loss(w1, w1g, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        return ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                                 interpret=True).sum()

    d1, d1g, d2 = jax.grad(loss, argnums=(0, 1, 2))(w1, w1g, w2)
    for dw in (d1, d1g, d2):
        assert np.all(np.asarray(dw[3]) == 0)
        assert np.any(np.asarray(dw[0]) != 0)


def test_fused_bf16_gradients_finite_and_close():
    case = (40, 24, 5, 16, 2, 5)
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.bfloat16)

    def loss(xf, w1, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        y = ops.moe_mlp_fused(xf, plan, w1, w2, None, activation="relu",
                              interpret=True)
        return y.astype(jnp.float32).sum()

    gx, g1, g2 = jax.grad(loss, argnums=(0, 1, 2))(xf, w1, w2)

    def loss_ref(xf, w1, w2):
        y = _oracle_mlp(xf, idx, gates, w1, None, w2, e, jax.nn.relu)
        return y.astype(jnp.float32).sum()

    rx, r1, r2 = jax.grad(loss_ref, argnums=(0, 1, 2))(xf, w1, w2)
    for a, b in ((gx, rx), (g1, r1), (g2, r2)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        assert np.isfinite(a).all()
        np.testing.assert_allclose(a, b, atol=0.15, rtol=0.15)


def test_plan_layout_consistency():
    """row_src/gate_tiles/new_pos describe the same permutation."""
    case = (100, 16, 6, 8, 3, 5)
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    m = n * k
    assert plan.row_src.shape[0] == plan.m_pad
    assert plan.m_pad % ops.TM == 0
    row_src = np.asarray(plan.row_src)
    new_pos = np.asarray(plan.new_pos)
    tok = np.repeat(np.arange(n), k)
    perm = np.asarray(plan.perm)
    # every sorted row's slot points back at its source token
    assert (row_src[new_pos] == tok[perm]).all()
    # slack slots hold the sentinel and a zero gate
    gate_pad = np.asarray(plan.gate_tiles).reshape(-1)
    slack = np.ones(plan.m_pad, bool)
    slack[new_pos] = False
    assert (row_src[slack] == n).all()
    assert (gate_pad[slack] == 0).all()
    # tiles are expert-pure: each valid slot's tile maps to its row's expert
    te = np.asarray(plan.tile_expert)
    e_sorted = np.asarray(idx.reshape(-1))[perm]
    assert (te[new_pos // ops.TM] == e_sorted).all()


def test_backward_reuses_forward_plan(monkeypatch):
    """Regression: the backward pass must NOT re-derive the tile layout.

    The seed implementation traced ``_tile_layout`` three times per grad call
    (forward, dX, dW); the planned custom_vjp must trace it exactly once."""
    calls = {"n": 0}
    orig = ops._tile_layout

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(ops, "_tile_layout", counting)

    m, k, n, e = 64, 32, 16, 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = 0.1 * jax.random.normal(key, (e, k, n), jnp.float32)
    gs = jnp.array([10, 20, 30, 4])
    jax.grad(lambda x, w: ops.cvmm(x, gs, w, impl="pallas_interpret").sum(),
             argnums=(0, 1))(x, w)
    assert calls["n"] == 1, f"_tile_layout traced {calls['n']}x (expected 1)"

    # fused pipeline: one plan per MoE call, zero extra layout derivations
    calls["n"] = 0
    case = (40, 24, 5, 16, 2, 5)
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)

    def loss(xf, w1, w2):
        plan = ops.make_moe_plan(idx, gates, 40, 5)
        return ops.moe_mlp_fused(xf, plan, w1, w2, None, activation="relu",
                                 interpret=True).sum()

    jax.grad(loss, argnums=(0, 1, 2))(xf, w1, w2)
    assert calls["n"] == 1, f"_tile_layout traced {calls['n']}x (expected 1)"


def test_fused_n_rows_not_multiple_of_8():
    """The streamed kernel gathers rows straight from HBM: no multiple-of-8
    row-count requirement (the retired whole-x kernel needed xe padded)."""
    case = (13, 24, 3, 16, 2, 3)
    n, d, e, g, k, _ = case
    assert n % 8 != 0
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    got = ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                            interpret=True)
    want = _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)

    def loss(xf, w1, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        return ops.moe_mlp_fused(xf, plan, w1, w2, None, activation="gelu",
                                 interpret=True).sum()

    def loss_ref(xf, w1, w2):
        act = lambda x: jax.nn.gelu(x, approximate=True)
        return _oracle_mlp(xf, idx, gates, w1, None, w2, e, act).sum()

    gf = jax.grad(loss, argnums=(0, 1, 2))(xf, w1, w2)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(xf, w1, w2)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_fused_all_slack_final_tile():
    """A row tile whose every row_src slot is the sentinel: the streamed gather
    issues ZERO DMAs for it (slack rows are skipped, not clamped-gathered) and
    the zero-filled scratch must yield finite outputs that are dropped."""
    n, d, e, g, k = 16, 16, 2, 8, 1
    key = jax.random.PRNGKey(3)
    kx, kg, k1, k2 = jax.random.split(key, 4)
    xf = jax.random.normal(kx, (n, d), jnp.float32)
    idx = jnp.zeros((n, k), jnp.int32)            # every token -> expert 0
    gates = jax.nn.softmax(jax.random.normal(kg, (n, k), jnp.float32), -1)
    w1 = 0.3 * jax.random.normal(k1, (e, d, g), jnp.float32)
    w2 = 0.3 * jax.random.normal(k2, (e, g, d), jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    row_src = np.asarray(plan.row_src).reshape(-1, ops.TM)
    assert (row_src[-1] == n).all(), "test setup: final tile must be all-slack"
    got = ops.moe_mlp_fused(xf, plan, w1, w2, None, activation="relu",
                            interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    want = _oracle_mlp(xf, idx, gates, w1, None, w2, e, jax.nn.relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    # gradients also stay finite and match (all-slack tiles contribute zero)
    g1 = jax.grad(lambda w1: ops.moe_mlp_fused(
        xf, ops.make_moe_plan(idx, gates, n, e), w1, w2, None,
        activation="relu", interpret=True).sum())(w1)
    r1 = jax.grad(lambda w1: _oracle_mlp(
        xf, idx, gates, w1, None, w2, e, jax.nn.relu).sum())(w1)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(r1),
                               atol=2e-4, rtol=2e-4)


def test_fused_single_expert_plan():
    """E=1 degenerates to a dense MLP with a gate; the streamed plan must
    handle a single expert (single weight block, one contiguous group)."""
    n, d, e, g, k = 37, 16, 1, 8, 1
    case = (n, d, e, g, k, e)
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    got = ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="silu",
                            interpret=True)
    want = _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.silu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_gather_rows_pallas_matches_take():
    """The streamed gather primitive == jnp.take with zero fill on sentinels."""
    n, d, e, k = 45, 24, 4, 2
    case = (n, d, e, 16, k, e)
    xf, idx, gates, *_ = _mk(case, jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    xe = ops._pad_lane(xf, 1)
    got = cvmm.cvmm_gather_rows_pallas(xe, plan.row_src, plan.run_start,
                                       plan.run_off, interpret=True)
    want = jnp.take(xe, plan.row_src, axis=0, mode="fill", fill_value=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def _replay_runs(plan, n_rows, x):
    """Chunk-table replay via the shared invariant oracle: this suite used to
    carry its own numpy re-execution; repro.analysis.plans is now the single
    source of those checks (CI's analysis gate runs the same code), so the
    test only asserts the oracle reports the plan clean."""
    from repro.analysis.plans import replay_chunk_table
    out, n_dma, findings = replay_chunk_table(plan, n_rows, x)
    assert findings == [], "\n".join(str(f) for f in findings)
    return out, n_dma


@pytest.mark.parametrize("case,skew", [((100, 16, 6, 8, 3, 5), False),
                                       ((300, 16, 3, 8, 1, 3), True)])
def test_plan_run_metadata_replays_gather(case, skew):
    """run_start/run_len describe exactly the row_src gather: replaying the
    chunk table in numpy reproduces take-with-zero-fill, never issues more
    descriptors than one-per-row, and fully batches contiguous tiles."""
    n, d, e, g, k, e_valid = case
    xf, idx, gates, *_ = _mk(case, jnp.float32)
    if skew:
        idx = jnp.zeros((n, k), jnp.int32)          # K=1, all rows -> expert 0
    plan = ops.make_moe_plan(idx, gates, n, e)
    x = np.asarray(ops._pad_lane(xf, 1))
    got, n_dma = _replay_runs(plan, n, x)
    want = np.asarray(jnp.take(jnp.asarray(x), plan.row_src, axis=0,
                               mode="fill", fill_value=0))
    np.testing.assert_array_equal(got, want)
    per_row = int((np.asarray(plan.row_src) < n).sum())
    assert 0 < n_dma <= per_row
    if skew:
        # fully contiguous row_src: every full tile is ONE size-TM descriptor
        rl = np.asarray(plan.run_len)
        assert int((rl == ops.TM).sum()) == n // ops.TM
        assert n_dma < per_row // 8


def test_fused_bwd_is_gather_free(monkeypatch):
    """Regression for the streamed backward: _fused_bwd must not materialize
    tile-aligned gathers via cvmm_gather_rows_pallas — dW/dX stream their
    unsorted operands straight from HBM."""
    def boom(*a, **kw):
        raise AssertionError("backward materialized a gather in HBM")

    monkeypatch.setattr(cvmm, "cvmm_gather_rows_pallas", boom)
    # ops.py no longer even imports the gather primitive; raising=False keeps
    # this tripwire armed should a future change reintroduce the import.
    monkeypatch.setattr(ops, "cvmm_gather_rows_pallas", boom, raising=False)

    case = (40, 24, 5, 16, 2, 5)
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)

    def loss(xf, w1, w1g, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        return ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                                 interpret=True).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(xf, w1, w1g, w2)
    assert all(np.isfinite(np.asarray(gr)).all() for gr in grads)


@pytest.mark.parametrize("stream_x", [True, False])
def test_dw_streamed_matches_unfused_dw(stream_x):
    """The streamed dW kernel == the unfused dW kernel fed the materialized
    gather, for both streamed sides (dW1's x-operand, dW2's gated g-operand)."""
    case = (52, 24, 4, 16, 2, 4)
    n, d, e, g, k, _ = case
    xf, idx, gates, _, _, _ = _mk(case, jnp.float32)
    key = jax.random.PRNGKey(7)
    plan = ops.make_moe_plan(idx, gates, n, e)
    xe = ops._pad_lane(xf, 1)
    d_pad, g_pad = xe.shape[1], ops.round_up(g, ops.LANE)
    x_pad = jnp.take(xe, plan.row_src, axis=0, mode="fill", fill_value=0)
    runs = (plan.row_src, plan.run_start, plan.run_off, plan.tile_expert)
    if stream_x:
        gg = jax.random.normal(key, (plan.m_pad, g_pad), jnp.float32)
        got = cvmm.cvmm_dw_streamed_pallas(xe, gg, *runs, e, stream_x=True,
                                           interpret=True)
        want = cvmm.cvmm_dw_pallas(x_pad, plan.tile_expert, gg, e,
                                   interpret=True)
    else:
        u = jax.random.normal(key, (plan.m_pad, g_pad), jnp.float32)
        got = cvmm.cvmm_dw_streamed_pallas(u, xe, *runs, e, stream_x=False,
                                           gate_tiles=plan.gate_tiles,
                                           interpret=True)
        gate = plan.gate_tiles.reshape(-1)[:, None]
        want = cvmm.cvmm_dw_pallas(u, plan.tile_expert, x_pad * gate, e,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_fused_supported_streams_past_whole_x_budget():
    """Regression for the lifted residency gate: the retired kernel kept the
    whole (N, K) activation block in VMEM and ``fused_supported`` rejected
    token counts past that budget; the streamed kernel must accept >= 4x the
    old boundary (and far beyond), while still rejecting non-tile-local
    activations and tile working sets that genuinely cannot fit."""
    d_model, g = 128, 128
    for dtype, glu in ((jnp.float32, True), (jnp.float32, False),
                      (jnp.bfloat16, True)):
        n_weights = 2 if glu else 1
        old = cvmm.legacy_whole_x_rows(d_model, jnp.dtype(dtype).itemsize,
                                       n_weights, n_out=1 + n_weights)
        assert old > 0
        for mult in (1, 4, 64):
            assert ops.fused_supported(mult * old, d_model, g, "relu",
                                       dtype, glu=glu)
    # still rejected: non-tile-local activation ...
    assert not ops.fused_supported(64, d_model, g, "softmax")
    # ... and a d_model whose per-step TILE working set exceeds VMEM
    assert not ops.fused_supported(64, 1_000_000, g, "relu")


def test_moe_sort_dispatch_uses_fused(monkeypatch):
    """apply_moe(dispatch='sort') routes through the fused pipeline when the
    default impl is pallas_fused, and matches the ragged-backed sort path."""
    from repro.configs import moe_ffn
    from repro.core import apply_moe, init_moe

    d_model, ne, g, k = 32, 4, 16, 2
    cfg = moe_ffn(ne, g, k, dispatch="sort")
    p = init_moe(jax.random.PRNGKey(0), d_model, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, d_model), jnp.float32)

    ops.set_default_impl("ragged")
    try:
        y_ref, _ = apply_moe(p, x, cfg)
    finally:
        ops.set_default_impl(None)

    called = {"fused": 0}
    orig = ops.moe_mlp_fused

    def spy(*a, **kw):
        called["fused"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(ops, "moe_mlp_fused", spy)
    ops.set_default_impl("pallas_fused")
    try:
        y_fused, _ = apply_moe(p, x, cfg)
    finally:
        ops.set_default_impl(None)
    assert called["fused"] == 1
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("activation,expect_fused", [
    ("relu", True),        # tile-local: gate says fused
    ("gelu", True),
    ("softmax", False),    # not tile-local: gate must force the unfused path
])
def test_moe_dispatch_consistent_with_gate(monkeypatch, activation,
                                           expect_fused):
    """apply_moe(dispatch='sort') under impl=pallas_fused must pick the fused
    vs unfused pipeline exactly as ``fused_supported`` answers — and both
    choices must agree numerically with the ragged-backed sort path."""
    from repro.configs import moe_ffn
    from repro.core import apply_moe, init_moe

    d_model, ne, g, k = 32, 4, 16, 2
    cfg = moe_ffn(ne, g, k, dispatch="sort", activation=activation)
    p = init_moe(jax.random.PRNGKey(0), d_model, cfg, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d_model), jnp.float32)

    assert ops.fused_supported(x.shape[0] * x.shape[1], d_model, g,
                               activation, x.dtype, glu=False) == expect_fused

    ops.set_default_impl("ragged")
    try:
        y_ref, _ = apply_moe(p, x, cfg)
    finally:
        ops.set_default_impl(None)

    called = {"fused": 0}
    orig = ops.moe_mlp_fused

    def spy(*a, **kw):
        called["fused"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(ops, "moe_mlp_fused", spy)
    ops.set_default_impl("pallas_fused")
    try:
        y, _ = apply_moe(p, x, cfg)
    finally:
        ops.set_default_impl(None)
    assert called["fused"] == (1 if expect_fused else 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Deeper stream pipelines (PR 6): the autotuner may pick n_buffers > 2; every
# streamed kernel must stay bit-compatible with the depth-2 default.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_buffers", [3, 4])
def test_gather_rows_deeper_pipeline_matches_take(n_buffers):
    n, d, e, k = 45, 24, 4, 2
    case = (n, d, e, 16, k, e)
    xf, idx, gates, *_ = _mk(case, jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    xe = ops._pad_lane(xf, 1)
    got = cvmm.cvmm_gather_rows_pallas(xe, plan.row_src, plan.run_start,
                                       plan.run_off, interpret=True,
                                       n_buffers=n_buffers)
    want = jnp.take(xe, plan.row_src, axis=0, mode="fill", fill_value=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_dw_streamed_depth3_matches_depth2():
    case = (52, 24, 4, 16, 2, 4)
    n, d, e, g, k, _ = case
    xf, idx, gates, _, _, _ = _mk(case, jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    xe = ops._pad_lane(xf, 1)
    g_pad = ops.round_up(g, ops.LANE)
    gg = jax.random.normal(jax.random.PRNGKey(7), (plan.m_pad, g_pad),
                           jnp.float32)
    runs = (plan.row_src, plan.run_start, plan.run_off, plan.tile_expert)
    d2 = cvmm.cvmm_dw_streamed_pallas(xe, gg, *runs, e, stream_x=True,
                                      interpret=True)
    d3 = cvmm.cvmm_dw_streamed_pallas(xe, gg, *runs, e, stream_x=True,
                                      interpret=True, n_buffers=3)
    np.testing.assert_allclose(np.asarray(d3), np.asarray(d2))


@pytest.mark.parametrize("glu", [False, True])
def test_fused_mlp_depth3_tiles_match_ragged(glu):
    """moe_mlp_fused with an explicit depth-3 FusedTiles plan (as a tuned
    cache would supply) matches the ragged oracle forward AND backward —
    including the 1-token-tile warmup guard on small grids."""
    case = (40, 24, 5, 16, 2, 5)
    n, d, e, g, k, _ = case
    xf, idx, gates, w1, w1g, w2 = _mk(case, jnp.float32)
    if not glu:
        w1g = None
    base = ops.fused_mlp_tiles(d, g, xf.dtype, glu=glu)
    tiles = base._replace(w1_nb=3, w1_train_nb=3, t0_nb=3, dw_nb=3)

    def loss_fused(xf, gates, w1, w1g, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        return ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                                 interpret=True, tiles=tiles).sum()

    def loss_ref(xf, gates, w1, w1g, w2):
        return _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu).sum()

    y = ops.moe_mlp_fused(xf, ops.make_moe_plan(idx, gates, n, e), w1, w2,
                          w1g, activation="relu", interpret=True, tiles=tiles)
    want = _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    argnums = (0, 1, 2, 3, 4) if glu else (0, 1, 2, 4)
    gf = jax.grad(loss_fused, argnums=argnums)(xf, gates, w1, w1g, w2)
    gr = jax.grad(loss_ref, argnums=argnums)(xf, gates, w1, w1g, w2)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# Boundary grids: fewer row tiles than pipeline buffers. The warmup must not
# issue tiles past the grid and the drain must still cover every tile — the
# exact regime the analysis pipeline pass proves symbolically; these runs
# confirm the proven schedule end-to-end through the real kernels.
# ---------------------------------------------------------------------------

# (n, d, e, g, k): m_pad/TM = ceil(n*k/TM) + e tiles — 2 tiles for e=1,
# 3 tiles for e=2, both strictly under the deepest pipeline.
_BOUNDARY_CASES = [(20, 16, 1, 8, 1), (20, 16, 2, 8, 1)]


@pytest.mark.parametrize("n_buffers", [3, 4])
@pytest.mark.parametrize("case", _BOUNDARY_CASES)
def test_fused_mlp_boundary_tiles_lt_buffers(case, n_buffers):
    n, d, e, g, k = case
    xf, idx, gates, w1, w1g, w2 = _mk((n, d, e, g, k, e), jnp.float32)
    plan = ops.make_moe_plan(idx, gates, n, e)
    n_tiles = plan.m_pad // ops.TM
    if n_tiles >= n_buffers:
        pytest.skip(f"grid has {n_tiles} tiles, not a boundary at depth "
                    f"{n_buffers}")
    base = ops.fused_mlp_tiles(d, g, xf.dtype, glu=True)
    tiles = base._replace(w1_nb=n_buffers, w1_train_nb=n_buffers,
                          t0_nb=n_buffers, dw_nb=n_buffers)

    y = ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                          interpret=True, tiles=tiles)
    want = _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)

    def loss_fused(xf, gates, w1, w1g, w2):
        p = ops.make_moe_plan(idx, gates, n, e)
        return ops.moe_mlp_fused(xf, p, w1, w2, w1g, activation="relu",
                                 interpret=True, tiles=tiles).sum()

    def loss_ref(xf, gates, w1, w1g, w2):
        return _oracle_mlp(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu).sum()

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3, 4))(xf, gates, w1, w1g, w2)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(xf, gates, w1, w1g, w2)
    for name, a, b in zip(("dx", "dgates", "dw1", "dw1g", "dw2"), gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


@pytest.mark.parametrize("n_buffers", [3, 4])
def test_gather_rows_single_tile_boundary(n_buffers):
    """A one-tile gather plan (n*s <= TM) at every deep pipeline: pure warmup
    + drain, no steady state at all."""
    n, rows, s = 30, 200, 4
    key = jax.random.PRNGKey(5)
    idx = jax.random.randint(key, (n, s), 0, rows)
    w = jnp.ones((n, s), jnp.float32)
    plan = ops.make_gather_plan(idx, w, rows)
    assert plan.row_src.shape[0] == ops.TM          # exactly one tile
    x = jax.random.normal(key, (rows, 2 * ops.LANE), jnp.float32)
    got = cvmm.cvmm_gather_rows_pallas(x, plan.row_src, plan.run_start,
                                       plan.run_off, interpret=True,
                                       n_buffers=n_buffers)
    want = jnp.take(x, plan.row_src, axis=0, mode="fill", fill_value=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
