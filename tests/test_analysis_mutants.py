"""Seeded-mutant validation of the kernel-contract analyzer.

Each test plants one representative bug in the REAL production artifact the
pass verifies (the shared schedule skeleton, the tuner's working-set
accounting, the sharding table) and asserts the pass flags it; the pinned
snapshot test asserts the current tree is clean AND that each pass keeps
verifying at least as many facts as it did when this suite was written — a
pass that silently stops checking cannot hide behind an empty findings list.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.analysis import run_passes
from repro.analysis.pipeline import check_pipeline, check_stream
from repro.analysis.plans import check_plans, replay_chunk_table, verify_plan
from repro.analysis.sharding import check_sharding
from repro.analysis.vmem import check_vmem
from repro.kernels import autotune, cvmm, ops
from repro.sharding import logical


# ---------------------------------------------------------------------------
# clean tree: every pass green, check counts pinned above a floor
# ---------------------------------------------------------------------------

# Floors are ~10% under the counts at the time this suite was written
# (pipeline 4374, plans 176, vmem 53120, sharding 1689): growth is free,
# silent shrinkage of a sweep fails here.
_CHECK_FLOORS = {"pipeline": 4000, "plans": 150, "vmem": 45000,
                 "sharding": 1500}


def test_current_tree_is_clean_and_sweeps_stay_wide():
    report = run_passes(("pipeline", "plans", "vmem", "sharding"))
    assert report.ok, "\n".join(str(f) for f in report.findings)
    for name, floor in _CHECK_FLOORS.items():
        assert report.checks[name] >= floor, (
            f"{name} pass verified only {report.checks[name]} facts "
            f"(floor {floor}) — did a sweep silently shrink?")


# ---------------------------------------------------------------------------
# mutant 1: dropped wait in the shared DMA schedule skeleton
# ---------------------------------------------------------------------------

def test_pipeline_flags_dropped_wait(monkeypatch):
    def mutant(i, m_tiles, n_buffers, *, issue, wait, when):
        when(i == 0, lambda: issue(0))
        for t in range(1, n_buffers - 1):
            when((i == 0) & (t < m_tiles), lambda t=t: issue(t))
        # wait(i) dropped: compute reads the slot while the DMA is in flight
        when(i + n_buffers - 1 < m_tiles, lambda: issue(i + n_buffers - 1))
        return cvmm.stream_slot(i, n_buffers)

    monkeypatch.setattr(cvmm, "stream_schedule_step", mutant)
    findings, _ = check_pipeline()
    kinds = {f.check for f in findings}
    assert "compute-unwaited" in kinds
    assert kinds & {"leaked-dma", "slot-overwrite", "coverage"}


# ---------------------------------------------------------------------------
# mutant 2: off-by-one warmup (unguarded prefetch past the grid)
# ---------------------------------------------------------------------------

def test_pipeline_flags_unguarded_warmup(monkeypatch):
    def mutant(i, m_tiles, n_buffers, *, issue, wait, when):
        when(i == 0, lambda: issue(0))
        for t in range(1, n_buffers - 1):
            # the (t < m_tiles) warmup guard dropped: boundary grids with
            # m_tiles < n_buffers prefetch tiles whose chunk tables and
            # scalar-prefetch rows do not exist
            when(i == 0, lambda t=t: issue(t))
        wait(i)
        when(i + n_buffers - 1 < m_tiles, lambda: issue(i + n_buffers - 1))
        return cvmm.stream_slot(i, n_buffers)

    monkeypatch.setattr(cvmm, "stream_schedule_step", mutant)
    findings, _ = check_pipeline()
    assert any(f.check == "issue-out-of-range" for f in findings)
    # only boundary grids are affected; long grids stay legal
    ok_f, _ = check_stream(8, 3, family="fused_w1")
    assert ok_f == []


# ---------------------------------------------------------------------------
# mutant 3: tuner working-set accounting under-reports -> busting candidates
# ---------------------------------------------------------------------------

def test_vmem_flags_busting_candidate(monkeypatch):
    # the classic drift: a kernel grows its scratch but the tuner's formula
    # is not updated — candidates that fit on paper crash at launch
    monkeypatch.setattr(autotune, "ws_fused_w1",
                        lambda k, tn, b, nw, no, nb=2: 0)
    findings, _ = check_vmem()
    kinds = {f.check for f in findings}
    assert "budget" in kinds and "formula-drift" in kinds
    assert any(f.check == "budget" and "fused_w1" in f.location
               for f in findings)


# ---------------------------------------------------------------------------
# mutant 4: the seed's duplicate-mesh-axis PKM rule
# ---------------------------------------------------------------------------

def test_sharding_flags_duplicate_axis_rule(monkeypatch):
    # the original seed bug: both 'heads' and 'pkm_keys' rule to 'model'
    monkeypatch.setitem(logical.PARAM_AXES, ("keys_a", 3),
                        ("heads", "embed", "pkm_keys"))
    findings, _ = check_sharding()
    dups = [f for f in findings if f.check == "duplicate-axis"]
    assert dups and any("keys_a" in f.location for f in dups)


# ---------------------------------------------------------------------------
# the plans oracle rejects corrupted plans (and ops' verify hook raises)
# ---------------------------------------------------------------------------

def _moe_plan(n=64, e=4, k=2):
    rng = np.random.RandomState(3)
    idx = jnp.asarray(rng.randint(0, e, size=(n, k)).astype(np.int32))
    gates = jnp.asarray(rng.rand(n, k).astype(np.float32))
    return ops.make_moe_plan(idx, gates, n, e), n


def test_plans_oracle_rejects_corrupted_row_src():
    # skewed routing (k=1, one expert) so multi-row DMA chunks are guaranteed
    idx = jnp.zeros((64, 1), jnp.int32)
    gates = jnp.ones((64, 1), jnp.float32)
    plan = ops.make_moe_plan(idx, gates, 64, 4)
    assert verify_plan(plan, 64) == []
    rl = np.asarray(plan.run_len)
    i = int(np.argmax(rl >= 2))      # a chunk the kernel copies as ONE DMA
    assert rl[i] >= 2
    slot = (i // 128) * 128 + int(np.asarray(plan.run_start)[i])
    rs = np.asarray(plan.row_src).copy()
    rs[slot + 1] = rs[slot]          # break the chunk's source contiguity:
    bad = plan._replace(row_src=jnp.asarray(rs))   # the DMA lands wrong rows
    assert any(f.check in ("chunk-noncontiguous", "gather-mismatch")
               for f in verify_plan(bad, 64))
    with pytest.raises(ValueError, match="plan invariant"):
        ops.plan_dma_stats(bad, 64, verify=True)


def test_plans_oracle_rejects_fetched_sentinel():
    plan, n = _moe_plan()
    rs = np.asarray(plan.row_src).copy()
    slack = np.nonzero(rs >= n)[0]
    if not slack.size:
        pytest.skip("routing produced no slack slots")
    rl = np.asarray(plan.run_len).copy()
    rst = np.asarray(plan.run_start).copy()
    rs[slack[0]] = 0                 # sentinel slot silently fetches row 0
    bad = plan._replace(row_src=jnp.asarray(rs), run_len=jnp.asarray(rl),
                        run_start=jnp.asarray(rst))
    assert any(f.check in ("sentinel-value", "sentinel-fetched", "coverage")
               for f in verify_plan(bad, n))


def test_replay_chunk_table_matches_take():
    plan, n = _moe_plan(100, 5, 3)
    x = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    out, n_dma, findings = replay_chunk_table(plan, n, x)
    assert findings == [] and n_dma > 0
    rs = np.asarray(plan.row_src)
    want = np.where((rs < n)[:, None], x[np.minimum(rs, n - 1)], 0.0)
    np.testing.assert_array_equal(out, want)


def test_check_plans_clean():
    findings, checks = check_plans()
    assert findings == [] and checks > 0
