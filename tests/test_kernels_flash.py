"""Flash-attention Pallas kernel (interpret mode) vs the pure-JAX chunked oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import flash_attention

CASES = [
    # (b, sq, sk, h, kv, d, causal)
    (2, 128, 128, 4, 2, 128, True),
    (1, 256, 256, 2, 2, 128, True),      # multi-block KV loop
    (1, 100, 100, 4, 4, 128, True),      # padded seq (non-multiple of 128)
    (2, 128, 128, 4, 2, 128, False),     # non-causal (encoder)
    (1, 384, 384, 8, 2, 128, True),      # GQA group 4
]


@pytest.mark.parametrize("case", CASES)
def test_flash_pallas_matches_oracle(case):
    b, sq, sk, h, kv, d, causal = case
    ks = jax.random.split(jax.random.PRNGKey(b * 31 + sq), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    got = flash_attention_pallas(q, k, v, causal=causal, scale=d ** -0.5,
                                 interpret=True)
    want = flash_attention(q, k, v, causal=causal, scale=d ** -0.5, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_pallas_bf16(dtype):
    b, sq, h, kv, d = 1, 128, 4, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sq, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sq, kv, d)).astype(dtype)
    got = flash_attention_pallas(q, k, v, causal=True, scale=d ** -0.5,
                                 interpret=True)
    want = flash_attention(q, k, v, causal=True, scale=d ** -0.5, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2, rtol=3e-2)
