"""Autotuner unit tests: heuristic parity with the retired static pickers,
the divisibility fix, the VMEM budget single-sourcing, and the persistent
cache lifecycle (hit-without-re-bench, corrupt/stale discard, concurrent
writers, budget invalidation).

Real micro-benchmarks never run here — tuned-mode tests inject a spy via
``autotune.set_benchmark_override`` and count invocations through
``autotune.STATS["microbench_calls"]`` (the same counter CI's cache-hit gate
reads), so the suite stays fast and deterministic in interpret-mode CI.
"""
import json
import os

import pytest

from repro.kernels import autotune, cvmm, ops
from repro.roofline import analysis


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    """Isolated tuner: private cache dir, clean state, disabled by default;
    restores env-driven behavior afterwards."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.delenv("REPRO_VMEM_BUDGET", raising=False)
    autotune.reset()
    autotune.enable(False)
    yield tmp_path
    autotune.enable(None)
    autotune.set_benchmark_override(None)
    autotune.reset()


def _spy(calls, time_of=None):
    """Fake micro-bench: records every invocation, returns ``time_of(tiles)``
    (default: constant, so roofline order decides)."""
    def fn(family, dims, tiles):
        calls.append((family, dict(dims), dict(tiles)))
        return 100.0 if time_of is None else time_of(tiles)
    return fn


# ---------------------------------------------------------------------------
# Heuristic mode: parity with the old static pickers, zero cost
# ---------------------------------------------------------------------------

def _ladder_pick(k_pad, n_pad, b, budget):
    """The retired fixed-ladder _pick_tn (pre-PR6 cvmm.py) for parity."""
    for tn in (512, 384, 256, 128):
        if n_pad % tn == 0 and \
                autotune.ws_matmul_tile(k_pad, tn, b) <= budget:
            return tn
    return None


def test_heuristic_matches_old_ladder_on_ladder_shapes(tuner):
    budget = cvmm.VMEM_BUDGET
    for n_pad in (128, 256, 384, 512):
        for k_pad in (128, 256, 640):
            for b in (2, 4):
                assert autotune.pick_tn(k_pad, n_pad, b, budget=budget) == \
                    _ladder_pick(k_pad, n_pad, b, budget), (k_pad, n_pad, b)


def test_divisibility_fix_n640(tuner):
    # the old ladder collapsed n_pad=640 (divisible by 128 but by neither
    # 384 nor 512) to tn=128; the enumeration finds the full-width tile
    assert autotune.pick_tn(128, 640, 4, budget=cvmm.VMEM_BUDGET) == 640
    assert _ladder_pick(128, 640, 4, cvmm.VMEM_BUDGET) == 128  # the old miss
    # under a budget too small for 640, the next dividing LANE multiple wins
    # (for 640 that is 128: 256/384/512 don't divide it)
    small = autotune.ws_matmul_tile(128, 128, 4)
    assert autotune.pick_tn(128, 640, 4, budget=small) == 128


def test_heuristic_no_io_no_bench(tuner):
    autotune.pick_tn(128, 512, 4, budget=cvmm.VMEM_BUDGET)
    autotune.fused_w1_tiles(128, 512, 4, 2, 3, budget=cvmm.VMEM_BUDGET)
    autotune.streamed_dw_tiles(128, 512, 4, budget=cvmm.VMEM_BUDGET)
    autotune.gather_tiles(128, 4, budget=cvmm.VMEM_BUDGET)
    assert autotune.STATS["microbench_calls"] == 0
    assert autotune.STATS["tuned"] == 0
    assert list(tuner.iterdir()) == []          # cache dir never touched


def test_heuristic_provenance_and_none(tuner):
    d = autotune.fused_w1_tiles(128, 512, 4, 2, 3, budget=cvmm.VMEM_BUDGET)
    assert d.provenance == "heuristic"
    assert d.tiles["tn"] == 512 and d.tiles["n_buffers"] == 2
    assert autotune.decide("pick_tn",
                           {"k_pad": 128, "n_pad": 512, "b": 4},
                           budget=1 << 10) == (None, "none")


# ---------------------------------------------------------------------------
# VMEM budget single-sourcing
# ---------------------------------------------------------------------------

def test_budget_from_hardware_model(tuner):
    hw = analysis.hardware_for("tpu")
    assert autotune.default_vmem_budget(hw) == \
        int(hw.vmem_bytes * autotune.KERNEL_VMEM_FRACTION)
    # cvmm's module-level budget comes from the same derivation (12 MiB for
    # the 16 MiB/core models)
    assert cvmm.VMEM_BUDGET == 12 * 2**20 == autotune.default_vmem_budget()


def test_budget_env_override(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "65536")
    assert autotune.default_vmem_budget() == 65536
    # decide() with no explicit budget picks up the override: nothing fits
    # 64 KiB at these shapes
    assert autotune.decide(
        "pick_tn", {"k_pad": 128, "n_pad": 512, "b": 4}).tiles is None


# ---------------------------------------------------------------------------
# Tuned mode + cache lifecycle
# ---------------------------------------------------------------------------

def test_tuned_winner_from_microbench(tuner):
    autotune.enable(True)
    calls = []
    # fake timings invert the heuristic preference: smallest tile "fastest"
    autotune.set_benchmark_override(_spy(calls, time_of=lambda t: t["tn"]))
    d = autotune.decide("pick_tn", {"k_pad": 128, "n_pad": 512, "b": 4},
                        budget=cvmm.VMEM_BUDGET)
    assert d == ({"tm": 128, "tn": 128}, "tuned")
    assert len(calls) == autotune.STATS["microbench_calls"] == \
        autotune.TUNE_TOP_K
    assert {c[2]["tn"] for c in calls} == {512, 256, 128}


def test_cache_hit_skips_microbench(tuner):
    autotune.enable(True)
    calls = []
    autotune.set_benchmark_override(_spy(calls))
    dims = {"k_pad": 128, "n_pad": 512, "b": 4}
    first = autotune.decide("pick_tn", dims, budget=cvmm.VMEM_BUDGET)
    n_bench = autotune.STATS["microbench_calls"]
    assert n_bench > 0 and first.provenance == "tuned"

    # fresh "process": drop the in-memory mirror, keep the on-disk file
    autotune.reset(memory_only=True)
    again = autotune.decide("pick_tn", dims, budget=cvmm.VMEM_BUDGET)
    assert again == first
    assert autotune.STATS["microbench_calls"] == n_bench   # zero new runs
    assert autotune.STATS["cache_hits"] >= 1


def test_cache_file_schema_and_atomic_publish(tuner):
    autotune.enable(True)
    autotune.set_benchmark_override(_spy([]))
    autotune.decide("pick_tn", {"k_pad": 128, "n_pad": 512, "b": 4},
                    budget=cvmm.VMEM_BUDGET)
    path = autotune.cache_path()
    data = json.load(open(path))
    assert data["schema"] == autotune.SCHEMA_VERSION
    assert "pick_tn|b=4|k_pad=128|n_pad=512" in data["entries"]
    entry = data["entries"]["pick_tn|b=4|k_pad=128|n_pad=512"]
    assert entry["provenance"] == "tuned" and "tiles" in entry
    # atomic publish: no .tune-* temp files survive a successful store
    leftovers = [f for f in os.listdir(os.path.dirname(path))
                 if f.startswith(".tune-")]
    assert leftovers == []


@pytest.mark.parametrize("payload", [
    "{ not json at all",                               # corrupt
    json.dumps({"schema": 999, "entries": {}}),        # future schema
    json.dumps({"schema": autotune.SCHEMA_VERSION}),   # missing entries
    json.dumps([1, 2, 3]),                             # wrong type
])
def test_invalid_cache_discarded_and_rebuilt(tuner, payload):
    autotune.enable(True)
    autotune.set_benchmark_override(_spy([]))
    path = autotune.cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        fh.write(payload)
    d = autotune.decide("pick_tn", {"k_pad": 128, "n_pad": 512, "b": 4},
                        budget=cvmm.VMEM_BUDGET)
    assert d.tiles is not None                   # never raises, still tunes
    assert autotune.STATS["cache_invalid"] >= 1
    rebuilt = json.load(open(path))              # file is valid again
    assert rebuilt["schema"] == autotune.SCHEMA_VERSION
    assert len(rebuilt["entries"]) == 1


def test_concurrent_writers_merge(tuner):
    autotune.enable(True)
    autotune.set_benchmark_override(_spy([]))
    d1 = {"k_pad": 128, "n_pad": 512, "b": 4}
    d2 = {"k_pad": 128, "n_pad": 256, "b": 4}
    autotune.decide("pick_tn", d1, budget=cvmm.VMEM_BUDGET)
    # second writer starts cold (no memory mirror), tunes a different key:
    # its read-merge-write must preserve the first writer's entry
    autotune.reset(memory_only=True)
    autotune.decide("pick_tn", d2, budget=cvmm.VMEM_BUDGET)
    entries = json.load(open(autotune.cache_path()))["entries"]
    assert {"pick_tn|b=4|k_pad=128|n_pad=512",
            "pick_tn|b=4|k_pad=128|n_pad=256"} <= set(entries)


def test_shrunk_budget_invalidates_cached_tiles(tuner):
    autotune.enable(True)
    calls = []
    autotune.set_benchmark_override(_spy(calls))
    dims = {"k_pad": 128, "n_pad": 512, "b": 4}
    big = autotune.decide("pick_tn", dims, budget=cvmm.VMEM_BUDGET)
    assert big.tiles["tn"] == 512                # constant spy -> roofline/
    autotune.reset(memory_only=True)             # heuristic order wins
    # a budget only tn=128 fits under: the cached 512 is no longer legal and
    # must NOT be honored
    small = autotune.ws_matmul_tile(128, 128, 4)
    d = autotune.decide("pick_tn", dims, budget=small)
    assert d == ({"tm": 128, "tn": 128}, "tuned")


def test_tuned_enumerates_pipeline_depths(tuner):
    autotune.enable(True)
    calls = []
    # deeper pipeline "faster": tuner should land on n_buffers=3
    autotune.set_benchmark_override(
        _spy(calls, time_of=lambda t: -t["n_buffers"]))
    d = autotune.fused_w1_tiles(128, 512, 4, 2, 3, budget=cvmm.VMEM_BUDGET)
    assert d.provenance == "tuned" and d.tiles["n_buffers"] == 3
    # while the heuristic (disabled) stays at the depth-2 default
    autotune.enable(False)
    h = autotune.fused_w1_tiles(128, 512, 4, 2, 3, budget=cvmm.VMEM_BUDGET)
    assert h == (dict(h.tiles), "heuristic") and h.tiles["n_buffers"] == 2


# ---------------------------------------------------------------------------
# ops-layer integration: one tile plan per call site, budget threaded
# ---------------------------------------------------------------------------

def test_ops_tile_plans_heuristic(tuner):
    fused = ops.fused_mlp_tiles(128, 512, glu=True)
    assert fused is not None and fused.provenance == "heuristic"
    assert (fused.w1_tn, fused.w2_tn, fused.dw_tb) == (512, 128, 512)
    planned = ops.planned_call_tiles(128, 512)
    assert planned is not None and planned.provenance == "heuristic"
    assert (planned.fwd_tn, planned.dx_tn) == (512, 128)
    assert autotune.STATS["microbench_calls"] == 0


def test_ops_tile_plans_respect_budget(tuner, monkeypatch):
    monkeypatch.setattr(cvmm, "VMEM_BUDGET", 1 << 10)
    assert ops.fused_mlp_tiles(128, 512, glu=True) is None
    assert ops.planned_call_tiles(128, 512) is None
    kplan = ops.plan_sort_kernels("pallas_fused", 128, 512, "relu",
                                  glu=True)
    assert kplan.rung == "ragged"


def test_gather_decision_and_fits(tuner):
    d = autotune.gather_tiles(128, 4, budget=cvmm.VMEM_BUDGET)
    assert d.tiles == {"tm": 128, "n_buffers": 2}
    assert autotune.gather_fits(128, 4, budget=cvmm.VMEM_BUDGET)
    assert not autotune.gather_fits(128, 4, budget=1 << 10)
