"""Property-based parity for the STREAMED fused CVMM pipeline.

The PR-1 fused kernel required the whole unsorted activation matrix to be
resident in VMEM, so ``ops.fused_supported`` rejected token counts past
``VMEM_BUDGET / row_bytes`` and production-sized calls silently fell back to
the unfused path. The streamed kernel double-buffers row tiles HBM->VMEM, so
these tests sweep token counts *straddling and far beyond* the old whole-x
boundary and check fwd+bwd parity against the pure-jnp ``ref`` oracle
(kernels/ref.py), in interpret mode on CPU.

To keep the boundary cheap to cross, ``cvmm.VMEM_BUDGET`` is shrunk to 1 MiB
for the kernel-parity tests (``legacy_whole_x_rows`` reads it at call time, so
the "old boundary" shrinks with it — the streaming logic itself is untouched
by the budget).

`hypothesis` is an OPTIONAL dev dependency (requirements-dev.txt): the
property test is skipped when it is missing, and a deterministic
non-hypothesis boundary sweep covers the same parity either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # module-level importorskip would hide the tests below;
    HAVE_HYPOTHESIS = False  # the property test reports as an explicit skip

from repro.kernels import cvmm, ops
from repro.kernels import ref as refk

D_MODEL = 128            # == LANE: k_pad is exactly d_model, no hidden padding
SMALL_BUDGET = 1 << 20   # 1 MiB: old whole-x boundary ~1280 fp32 rows


@pytest.fixture
def small_vmem_budget(monkeypatch):
    monkeypatch.setattr(cvmm, "VMEM_BUDGET", SMALL_BUDGET)


def _old_boundary(dtype, glu) -> int:
    """Max token count the retired whole-x kernel's gate accepted (worst case:
    training outputs), under the currently-set VMEM_BUDGET."""
    n_weights = 2 if glu else 1
    return cvmm.legacy_whole_x_rows(D_MODEL, jnp.dtype(dtype).itemsize,
                                    n_weights, n_out=1 + n_weights)


def _mk(n, e, g, k, e_valid, dtype, seed, skew=False):
    key = jax.random.PRNGKey(seed)
    kx, ki, kg, k1, k2, k3 = jax.random.split(key, 6)
    xf = jax.random.normal(kx, (n, D_MODEL), jnp.float32).astype(dtype)
    if skew:                 # every token on one expert: maximally ragged
        idx = jnp.zeros((n, k), jnp.int32)
    else:
        idx = jax.random.randint(ki, (n, k), 0, e_valid)
    gates = jax.nn.softmax(jax.random.normal(kg, (n, k), jnp.float32), -1)
    w1 = (0.3 * jax.random.normal(k1, (e, D_MODEL, g), jnp.float32)).astype(dtype)
    w1g = (0.3 * jax.random.normal(k2, (e, D_MODEL, g), jnp.float32)).astype(dtype)
    w2 = (0.3 * jax.random.normal(k3, (e, g, D_MODEL), jnp.float32)).astype(dtype)
    return xf, idx, gates, w1, w1g, w2


def _oracle_mlp_ref(xf, idx, gates, w1, w1g, w2, e, act):
    """The sort-path expert MLP on the pure-jnp one-hot ``ref`` oracle."""
    n, k = idx.shape
    e_flat = idx.reshape(-1)
    g_flat = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), k)
    perm = jnp.argsort(e_flat, stable=True)
    gs = jnp.bincount(e_flat, length=e).astype(jnp.int32)
    xs = xf[tok[perm]]
    h = refk.cvmm_ref(xs, gs, w1)
    u = act(h)
    if w1g is not None:
        u = u * refk.cvmm_ref(xs, gs, w1g)
    y = refk.cvmm_ref(u, gs, w2)
    y = y * g_flat[perm][:, None].astype(y.dtype)
    return jnp.zeros_like(xf).at[tok[perm]].add(y)


def _check_parity(n, e, g, k, e_valid, dtype, seed, glu, *, bwd=True,
                  skew=False):
    xf, idx, gates, w1, w1g, w2 = _mk(n, e, g, k, e_valid, dtype, seed, skew)
    if not glu:
        w1g = None
    f32 = dtype == jnp.float32
    tol_f, tol_b = (1e-5, 3e-4) if f32 else (0.12, 0.2)

    plan = ops.make_moe_plan(idx, gates, n, e)
    got = ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                            interpret=True)
    want = _oracle_mlp_ref(xf, idx, gates, w1, w1g, w2, e, jax.nn.relu)
    want = np.asarray(want, np.float32)
    if not f32:
        # The oracle rounds u (and the gate multiply) through bf16 while the
        # kernel keeps them in the f32 epilogue, so elements with partial
        # cancellation in the w2 accumulation differ by an ABSOLUTE margin set
        # by the output scale, not by their own magnitude.
        tol_f = max(tol_f, 0.02 * float(np.abs(want).max()))
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               atol=tol_f, rtol=tol_f)
    if not bwd:
        return

    def loss_fused(xf, gates, w1, w2):
        plan = ops.make_moe_plan(idx, gates, n, e)
        return ops.moe_mlp_fused(xf, plan, w1, w2, w1g, activation="relu",
                                 interpret=True).astype(jnp.float32).sum()

    def loss_ref(xf, gates, w1, w2):
        return _oracle_mlp_ref(xf, idx, gates, w1, w1g, w2, e,
                               jax.nn.relu).astype(jnp.float32).sum()

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(xf, gates, w1, w2)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(xf, gates, w1, w2)
    for name, a, b in zip(("dx", "dgates", "dw1", "dw2"), gf, gr):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        assert np.isfinite(a).all(), name
        # Same rationale as the forward check: the oracle rounds intermediates
        # through bf16 while the kernels keep f32 epilogues, so bf16 elements
        # with partial cancellation differ by an ABSOLUTE margin set by the
        # gradient's scale rather than their own magnitude.
        atol = tol_b if f32 else max(tol_b, 0.02 * float(np.abs(b).max()))
        np.testing.assert_allclose(a, b, atol=atol, rtol=tol_b, err_msg=name)


def test_streamed_parity_at_4x_old_budget(small_vmem_budget):
    """THE acceptance check: fused_supported accepts >= 4x the old whole-x
    budget and the streamed kernel matches the ref oracle there, fwd+bwd."""
    glu, dtype = True, jnp.float32
    old = _old_boundary(dtype, glu)
    n = 4 * old
    assert ops.fused_supported(n, D_MODEL, 64, "relu", dtype, glu=glu)
    _check_parity(n, e=4, g=64, k=1, e_valid=4, dtype=dtype, seed=0, glu=glu,
                  bwd=True)


@pytest.mark.parametrize("dtype,glu", [(jnp.float32, True),
                                       (jnp.bfloat16, False)])
def test_streamed_parity_straddles_old_boundary(small_vmem_budget, dtype, glu):
    """Deterministic sweep (runs with or without hypothesis): token counts just
    below and just above the old whole-x VMEM boundary agree with the oracle,
    so nothing structural changes as the kernel crosses it."""
    old = _old_boundary(dtype, glu)
    f32 = dtype == jnp.float32
    for i, n in enumerate((old - 257, old + 1, old + 513)):
        _check_parity(n, e=3, g=32, k=2, e_valid=3, dtype=dtype, seed=i,
                      glu=glu, bwd=(i == 1) and f32)


def test_streamed_bwd_run_batched_long_runs(small_vmem_budget):
    """Run-batching acceptance: K=1 with every token on one expert makes
    row_src fully contiguous — the plan must collapse each full tile to a
    single size-TM DMA descriptor — and the gather-free streamed backward
    must match the oracle past the old whole-x boundary in that regime."""
    dtype, glu = jnp.float32, False
    n = _old_boundary(dtype, glu) + 3 * cvmm.TM + 7
    xf, idx, gates, w1, w1g, w2 = _mk(n, 2, 32, 1, 1, dtype, seed=11,
                                      skew=True)
    plan = ops.make_moe_plan(idx, gates, n, 2)
    rl = np.asarray(plan.run_len)
    assert int((rl == cvmm.TM).sum()) == n // cvmm.TM
    n_dma = int((rl > 0).sum())
    per_row = int((np.asarray(plan.row_src) < n).sum())
    assert n_dma <= per_row // 64      # ~1 descriptor per tile, not per row
    _check_parity(n, e=2, g=32, k=1, e_valid=1, dtype=dtype, seed=11,
                  glu=glu, bwd=True, skew=True)


def test_streamed_bwd_bf16_past_boundary(small_vmem_budget):
    """bf16 fwd+bwd parity past the old whole-x boundary: the streamed dW/dX
    kernels must keep bf16 operands finite and close to the oracle."""
    dtype, glu = jnp.bfloat16, True
    n = _old_boundary(dtype, glu) + 129
    _check_parity(n, e=3, g=32, k=1, e_valid=2, dtype=dtype, seed=5, glu=glu,
                  bwd=True)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_streamed_parity_property(small_vmem_budget):
    """Random token counts straddling the old boundary x ragged/empty expert
    groups x GLU on/off x fp32+bf16, fwd and bwd vs the ref oracle."""

    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.data())
    def run(data):
        glu = data.draw(st.booleans(), label="glu")
        f32 = data.draw(st.booleans(), label="fp32")
        dtype = jnp.float32 if f32 else jnp.bfloat16
        old = _old_boundary(dtype, glu)
        n = old + data.draw(st.integers(-300, 600), label="boundary_offset")
        e = data.draw(st.integers(2, 4), label="n_experts")
        # e_valid < e leaves experts with EMPTY groups; skew packs every token
        # onto one expert (maximally ragged group sizes)
        e_valid = data.draw(st.integers(1, e), label="e_valid")
        skew = data.draw(st.booleans(), label="skew")
        g = data.draw(st.sampled_from((32, 64)), label="expert_size")
        k = data.draw(st.integers(1, 2), label="k")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        _check_parity(n, e, g, k, e_valid, dtype, seed, glu, bwd=f32,
                      skew=skew)

    run()
