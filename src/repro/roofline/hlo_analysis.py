"""HLO-text cost analysis with while-loop trip-count awareness.

Why not ``compiled.cost_analysis()``? It visits each while body ONCE (verified
empirically), so for scan-over-layers models it reports 1/n_layers of the real cost,
and it has no collective breakdown at all. This module parses the post-SPMD optimized
HLO text (per-device module):

  * builds a per-computation op list with resolved operand shapes,
  * propagates execution multipliers from ENTRY through while ops using their
    ``known_trip_count`` backend configs,
  * computes dot FLOPs (2 * |out| * |contract|), HBM bytes per op (operands + output,
    with in-place dynamic-update-slice counted as slice-sized), and per-collective
    *wire* bytes using ring-algorithm factors:

        all-gather      out * (g-1)/g
        reduce-scatter  out * (g-1)
        all-reduce      2 * size * (g-1)/g
        all-to-all      total * (g-1)/g
        collective-permute  size

All quantities are per-device (the module is already partitioned).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# output type is either a tuple "(...)" (may contain /*index=N*/ comments and
# nested parens like layout tiles T(8,128)) or a single token.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|\S+)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|\{)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def type_bytes(t: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def type_elems(t: str) -> int:
    m = _SHAPE_RE.search(t)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(t: str) -> List[int]:
    m = _SHAPE_RE.search(t)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_type: str
    line: str


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    dot_flops_by_name: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "HLOCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        self.coll_count += int(other.coll_count * mult)
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    entry_name: Optional[str] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_RE.match(line)
            if m and ("->" in line or line.rstrip().endswith("{")):
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry_name = current
            continue
        if current is not None and line.strip().startswith(("%", "ROOT")):
            comps[current].append(line)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(line: str, out_type: str, shapes: Dict[str, str]) -> float:
    out_elems = type_elems(out_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not m:
        return 2.0 * out_elems           # degenerate dot
    cdims = [int(d) for d in m.group(1).split(",") if d]
    # resolve lhs operand shape
    paren = line[line.index("(") + 1:]
    ops = _OPERAND_RE.findall(paren.split(")", 1)[0])
    lhs_dims: List[int] = []
    # prefer inline shape if printed, else symbol table
    inline = _SHAPE_RE.search(paren.split(",")[0])
    if inline and inline.group(2):
        lhs_dims = [int(d) for d in inline.group(2).split(",") if d]
    elif ops and ops[0] in shapes:
        lhs_dims = _shape_dims(shapes[ops[0]])
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_elems * k


_ZERO_BYTE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                  "after-all", "partition-id", "replica-id", "iota"}


def _analyze_computation(lines: List[str]) -> Tuple[HLOCost, List[Tuple[str, int]]]:
    """Returns (cost of one pass, [(while_body, trip_count), ...])."""
    cost = HLOCost()
    whiles: List[Tuple[str, int]] = []
    shapes: Dict[str, str] = {}
    parsed: List[Tuple[str, str, str, str]] = []
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_type, kind = m.group(1), m.group(2), m.group(3)
        shapes[name] = out_type
        parsed.append((name, out_type, kind, line))

    for name, out_type, kind, line in parsed:
        if kind == "while":
            body = _BODY_RE.search(line)
            trip = _TRIP_RE.search(line)
            whiles.append((body.group(1) if body else "",
                           int(trip.group(1)) if trip else 1))
            continue
        if kind in _ZERO_BYTE_OPS:
            continue
        out_bytes = type_bytes(out_type)
        # operand bytes from symbol table
        paren = line[line.index("(") + 1:].split(")", 1)[0]
        operand_names = _OPERAND_RE.findall(paren)
        in_bytes = sum(type_bytes(shapes.get(o, "")) for o in operand_names)

        if kind in COLLECTIVES:
            g = _group_size(line)
            size = out_bytes
            if kind == "all-gather":
                wire = size * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                wire = size * (g - 1)
            elif kind == "all-reduce":
                wire = 2.0 * size * (g - 1) / max(g, 1)
            elif kind == "all-to-all":
                wire = size * (g - 1) / max(g, 1)
            else:  # collective-permute
                wire = size
            cost.coll_wire_bytes += wire
            cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) + wire
            cost.coll_count += 1
            cost.hbm_bytes += out_bytes + in_bytes
            continue

        if kind == "dot":
            f = _dot_flops(line, out_type, shapes)
            cost.flops += f
            cost.dot_flops_by_name[name] = f
            cost.hbm_bytes += out_bytes + in_bytes
        elif kind == "dynamic-update-slice":
            upd = (type_bytes(shapes.get(operand_names[1], ""))
                   if len(operand_names) > 1 else out_bytes)
            cost.hbm_bytes += 2 * upd          # read update + write slice (in-place)
        elif kind == "dynamic-slice":
            cost.hbm_bytes += 2 * out_bytes
        elif kind == "fusion":
            cost.hbm_bytes += out_bytes + in_bytes
            # elementwise flops inside fusions ~ output elems (cheap estimate)
            cost.flops += type_elems(out_type)
        else:
            cost.hbm_bytes += out_bytes + in_bytes
    return cost, whiles


def analyze_hlo_text(text: str) -> HLOCost:
    comps = _split_computations(text)
    per_comp: Dict[str, Tuple[HLOCost, List[Tuple[str, int]]]] = {}
    for name, lines in comps.items():
        per_comp[name] = _analyze_computation(lines)

    total = HLOCost()
    seen: Dict[str, float] = defaultdict(float)

    def visit(comp: str, mult: float) -> None:
        if comp not in per_comp:
            return
        seen[comp] += mult
        cost, whiles = per_comp[comp]
        total.add(cost, mult)
        for body, trip in whiles:
            visit(body, mult * trip)

    visit("__entry__", 1.0)
    return total
