"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_wire_bytes_per_device / ICI_bw

Hardware model: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (we use
one link-equivalent per chip; multi-link meshes scale this linearly). The HLO module
is post-SPMD, so all quantities are already per-device. The cross-pod 'pod' axis is
DCN (~6.25 GB/s/host effective); collectives whose replica groups span pods are the
multi-pod dry-run's concern and appear in coll_by_kind.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from .hlo_analysis import analyze_hlo_text


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float            # bf16 FLOP/s per chip
    hbm_bw: float                # B/s per chip
    ici_bw: float                # B/s per link per chip
    hbm_bytes: float             # capacity per chip
    # Fast on-chip tile memory per core (VMEM on TPU). The kernel autotuner
    # (kernels/autotune.py) slices its per-kernel working-set budget from this
    # instead of hard-coding bytes; off-TPU models mirror the TPU value so
    # interpret-mode tile choices match the TPU defaults bit-for-bit.
    vmem_bytes: float = 16 * 2**20


V5E = Hardware(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
               hbm_bytes=16e9)

# Interpret-mode stand-in for CPU CI runs: throughput numbers only order the
# autotuner's roofline pruning (relative cost), they are not calibrated.
CPU_INTERPRET = Hardware(name="cpu_interpret", peak_flops=2e11, hbm_bw=4e10,
                         ici_bw=1e9, hbm_bytes=32e9)

# Coarse A100-class placeholder so gpu backends get a sane pruning model.
GPU_GENERIC = Hardware(name="gpu_generic", peak_flops=312e12, hbm_bw=2.0e12,
                       ici_bw=300e9, hbm_bytes=80e9)

# jax.default_backend() name -> hardware model (kernels/autotune.py resolves
# the backend; this module stays importable without jax).
HARDWARE_MODELS = {"tpu": V5E, "cpu": CPU_INTERPRET, "gpu": GPU_GENERIC}


def hardware_for(backend: str) -> Hardware:
    """Hardware model for a jax backend name (unknown backends fall back to
    the TPU model — conservative VMEM, TPU-shaped roofline)."""
    return HARDWARE_MODELS.get(backend, V5E)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device seconds
    compute_s: float
    memory_s: float
    collective_s: float
    # raw per-device quantities
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    # usefulness
    model_flops_global: float
    hlo_flops_global: float
    # memory fit
    memory_analysis: Dict[str, float]
    # xla cross-check (body-once semantics)
    xla_cost_analysis: Dict[str, float]

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_global / max(self.hlo_flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the USEFUL model flops achieve at the bound step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (self.model_flops_global / self.n_chips / t) / V5E.peak_flops

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(bound=self.bound, step_time_s=self.step_time_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape, n_tokens: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.
    Train counts fwd+bwd (the 6x); decode/prefill use 2*N*D (fwd only)."""
    pc = cfg.param_counts()
    n_active = pc["active"]
    if n_tokens is None:
        if shape.mode == "decode":
            n_tokens = shape.global_batch            # one token per sequence
        else:
            n_tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n_active * n_tokens


def analyze_compiled(compiled, *, arch: str, shape, mesh_name: str, n_chips: int,
                     cfg, hw: Hardware = V5E) -> RooflineReport:
    text = compiled.as_text()
    cost = analyze_hlo_text(text)
    try:
        ca = compiled.cost_analysis()
        ca = ca if isinstance(ca, dict) else ca[0]
        xla_ca = {k: float(v) for k, v in ca.items()
                  if k in ("flops", "bytes accessed")}
    except Exception:
        xla_ca = {}
    try:
        ma = compiled.memory_analysis()
        mem = {k: float(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes")}
        mem["total_hbm_bytes"] = (mem["argument_size_in_bytes"]
                                  + mem["output_size_in_bytes"]
                                  + mem["temp_size_in_bytes"]
                                  - mem["alias_size_in_bytes"])
    except Exception:
        mem = {}

    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        compute_s=cost.flops / hw.peak_flops,
        memory_s=cost.hbm_bytes / hw.hbm_bw,
        collective_s=cost.coll_wire_bytes / hw.ici_bw,
        flops=cost.flops, hbm_bytes=cost.hbm_bytes,
        coll_bytes=cost.coll_wire_bytes, coll_by_kind=dict(cost.coll_by_kind),
        model_flops_global=mf, hlo_flops_global=cost.flops * n_chips,
        memory_analysis=mem, xla_cost_analysis=xla_ca)
