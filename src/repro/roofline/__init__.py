from .analysis import RooflineReport, analyze_compiled, V5E
from .hlo_analysis import HLOCost, analyze_hlo_text

__all__ = ["RooflineReport", "analyze_compiled", "V5E", "HLOCost",
           "analyze_hlo_text"]
