"""Dense / GLU / Top-K-activation feedforward blocks (paper Sec. 2 & 3.1).

Functional init/apply convention used across the repo::

    params = init_*(key, d_model, cfg, n_layers, dtype)
    y, aux = apply_*(params, x, cfg, ...)

x: (..., d_model). aux follows the uniform contract (dispatch.base_aux).

Framework lowering (paper Sec. 2 / core/dispatch.py): the top-K activation is
the framework's simplest non-trivial selection rule — ``lax.top_k`` over
u = act(W1 x) picks K of the d_ff rows of W2, and the down-projection is the
shared weighted aggregation primitive (``dispatch.weighted_value_sum`` with
W2 as the value table): only the K surviving activations flow through the
planned gather-sum instead of the dense (..., d_ff) @ W2 matmul the mask used
to pay for. The paper's caveat stands: the full up-projection is still
computed to *find* the top-K (Sec. 3.1), so only the down-projection is
sparse. The masked dense down-projection survives as the ``impl="dense"``
oracle reference (``_down_dense``).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..common import act_fn
from ..configs.base import FFNConfig
from . import init as initlib
from .dispatch import (Selection, base_aux, resolve_impl, selection_usage,
                       weighted_value_sum)


def init_dense(key, d_model: int, cfg: FFNConfig, n_layers: int,
               dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    del ep_degree                      # uniform registry signature; no EP here
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = initlib.dense_std_in(d_model, n_layers)
    s2 = initlib.dense_std_out(cfg.d_ff, n_layers)
    p = {
        "w1": initlib.normal(k1, (d_model, cfg.d_ff), s1, dtype),
        "w2": initlib.normal(k2, (cfg.d_ff, d_model), s2, dtype),
    }
    if cfg.kind == "glu":
        p["w3"] = initlib.normal(k3, (d_model, cfg.d_ff), s1, dtype)
    return p


def _down_dense(u: jax.Array, w2: jax.Array, k: int) -> jax.Array:
    """impl="dense" oracle: arg-topk mask (Eq. 6-7) + full down-projection.

    With ReLU, u >= 0, so thresholding at the K-th largest value zeroes
    exactly the complement set; the sparse path below computes the identical
    sum from the K selected rows directly."""
    kth = jax.lax.top_k(u, k)[0][..., -1:]
    u = jnp.where(u >= kth, u, 0.0).astype(u.dtype)
    return jnp.einsum("...f,fd->...d", u, w2)


def apply_dense(params: Dict, x: jax.Array, cfg: FFNConfig, *,
                rng=None, train: bool = False,
                collect_stats: bool = False) -> Tuple[jax.Array, Dict]:
    """dense | glu | topk. Top-K (Sec. 3.1): keep the K largest activations of u.

    Note (paper): top-K saves only the DOWN-projection compute; the full up-projection
    u = act(W1 x) is still required to *find* the top-K.
    """
    del rng, train                     # uniform registry signature; no dropout here
    act = act_fn(cfg.activation)
    aux = base_aux()
    u = act(jnp.einsum("...d,df->...f", x, params["w1"].astype(x.dtype)))
    if cfg.kind == "glu":
        u = u * jnp.einsum("...d,df->...f", x, params["w3"].astype(x.dtype))
    w2 = params["w2"].astype(x.dtype)
    if cfg.kind == "topk" and cfg.topk_k and cfg.topk_k < cfg.d_ff:
        lead = x.shape[:-1]
        uf = u.reshape(-1, cfg.d_ff)
        if resolve_impl(cfg) == "dense":
            y = _down_dense(uf, w2, cfg.topk_k)
            if collect_stats:
                vals, idx = jax.lax.top_k(uf, cfg.topk_k)
                aux["usage"] = selection_usage(
                    Selection(idx=idx, weights=vals, n_items=cfg.d_ff))
            return y.reshape(*lead, -1), aux
        # Sparse down-projection through the shared planned layer: the K
        # surviving activations are the selection weights, W2 the value table.
        vals, idx = jax.lax.top_k(uf, cfg.topk_k)
        sel = Selection(idx=idx, weights=vals, n_items=cfg.d_ff)
        y = weighted_value_sum(w2, sel, uf.shape[0], cfg)
        if collect_stats:
            aux["usage"] = selection_usage(sel)              # channel usage
        return y.reshape(*lead, -1), aux
    y = jnp.einsum("...f,fd->...d", u, w2)
    return y, aux
