"""Dense / GLU / Top-K-activation feedforward blocks (paper Sec. 2 & 3.1).

Functional init/apply convention used across the repo::

    params = init_*(key, d_model, cfg, n_layers, dtype)
    y, aux = apply_*(params, x, cfg, ...)

x: (..., d_model). aux is a dict of scalars (regularizer losses etc.).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..common import act_fn
from ..configs.base import FFNConfig
from . import init as initlib


def init_dense(key, d_model: int, cfg: FFNConfig, n_layers: int,
               dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = initlib.dense_std_in(d_model, n_layers)
    s2 = initlib.dense_std_out(cfg.d_ff, n_layers)
    p = {
        "w1": initlib.normal(k1, (d_model, cfg.d_ff), s1, dtype),
        "w2": initlib.normal(k2, (cfg.d_ff, d_model), s2, dtype),
    }
    if cfg.kind == "glu":
        p["w3"] = initlib.normal(k3, (d_model, cfg.d_ff), s1, dtype)
    return p


def apply_dense(params: Dict, x: jax.Array, cfg: FFNConfig) -> Tuple[jax.Array, Dict]:
    """dense | glu | topk. Top-K (Sec. 3.1): keep the K largest activations of u.

    Note (paper): top-K saves only the DOWN-projection compute; the full up-projection
    u = act(W1 x) is still required to *find* the top-K.
    """
    act = act_fn(cfg.activation)
    u = act(jnp.einsum("...d,df->...f", x, params["w1"].astype(x.dtype)))
    if cfg.kind == "glu":
        u = u * jnp.einsum("...d,df->...f", x, params["w3"].astype(x.dtype))
    if cfg.kind == "topk" and cfg.topk_k and cfg.topk_k < cfg.d_ff:
        # arg-topk mask (Eq. 6-7). With ReLU, u >= 0, so thresholding at the K-th
        # largest value zeroes exactly the complement set.
        kth = jax.lax.top_k(u, cfg.topk_k)[0][..., -1:]
        u = jnp.where(u >= kth, u, 0.0).astype(u.dtype)
    y = jnp.einsum("...f,fd->...d", u, params["w2"].astype(x.dtype))
    return y, {}
