"""Expert selection functions (paper Sec. 3.3-5).

All selectors share the contract::

    gates, idx, info = select_experts(logits, cfg, rng=..., train=...)

where ``logits = x @ W3`` (+ optional noise net), ``gates`` are the (N, K) weighting
scores s[e] of Eq. 11, ``idx`` the (N, K) selected expert indices, and ``info`` carries
the full selection distribution used by the regularizers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import FFNConfig


class SelectionInfo(NamedTuple):
    probs: jax.Array        # (N, E) softmax(W3 x) -- Eq. 20 (always softmax)
    sel: jax.Array          # (N, E) the actual selector activation output
    idx: jax.Array          # (N, K)
    gates: jax.Array        # (N, K)


def norm_topk(s: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Paper Eqs. 23-25: keep top-K of s, renormalize to sum 1. Returns (gates, idx)."""
    vals, idx = jax.lax.top_k(s, k)
    gates = vals / (jnp.sum(vals, axis=-1, keepdims=True) + 1e-9)
    return gates, idx


def two_stage_topk(ua: jax.Array, ub: jax.Array, k: int,
                   n_candidates: int = 0
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-stage product-key top-K (paper Sec. 3.2 / Lample et al. 2019).

    The full score grid is u[i] = ub[i // ns] + ua[i mod ns] over
    n_values = ns**2 entries; this never materializes it. Stage 1 takes the
    top-C of each half independently; stage 2 re-scores only the C*C
    candidate grid and takes the final top-K. For C >= K the true top-K of
    the full grid is provably contained in the candidate grid (each of the
    true top-K has both halves in their respective top-K <= top-C), so the
    result is exact while the work is O(ns + C^2) per token instead of
    O(ns^2) — `n_values` can reach 1M+ (ns=1024) without a
    (n_tokens, n_values) score matrix ever existing.

    ua, ub: (..., ns) sub-key score halves. Returns ``(scores, sel_a, sel_b)``
    each (..., K), where the flat value index is ``sel_b * ns + sel_a``.
    """
    c = n_candidates or k
    va, ia = jax.lax.top_k(ua, c)
    vb, ib = jax.lax.top_k(ub, c)
    cand = va[..., :, None] + vb[..., None, :]            # (..., C, C)
    cand = cand.reshape(*cand.shape[:-2], c * c)
    top, flat = jax.lax.top_k(cand, k)                    # over C*C, not ns*ns
    sel_a = jnp.take_along_axis(ia, flat // c, axis=-1)
    sel_b = jnp.take_along_axis(ib, flat % c, axis=-1)
    return top, sel_a, sel_b


def sinkhorn(logits: jax.Array, n_iters: int = 8) -> jax.Array:
    """Log-space Sinkhorn normalization (Clark et al. 2022 S-BASE routing).

    Returns a (N, E) soft assignment matrix whose columns are balanced: each expert
    receives ~N/E total mass. Rows sum to 1.
    """
    n, e = logits.shape
    f = jnp.zeros((n, 1), logits.dtype)   # row potentials
    g = jnp.zeros((1, e), logits.dtype)   # col potentials
    # target marginals: rows sum 1, cols sum N/E
    log_row = jnp.zeros((n, 1), logits.dtype)
    log_col = jnp.full((1, e), jnp.log(n / e), logits.dtype)

    def body(_, fg):
        f, g = fg
        g = log_col - jax.nn.logsumexp(logits + f, axis=0, keepdims=True)
        f = log_row - jax.nn.logsumexp(logits + g, axis=1, keepdims=True)
        return f, g

    f, g = jax.lax.fori_loop(0, n_iters, body, (f, g))
    return jnp.exp(logits + f + g)


def expert_dropout_mask(rng: jax.Array, n_experts: int, rate: float) -> jax.Array:
    """Paper Eq. 22: Bernoulli(1-delta) mask over whole experts, NO rescaling."""
    return jax.random.bernoulli(rng, 1.0 - rate, (n_experts,))


def select_experts(logits: jax.Array, cfg: FFNConfig, *,
                   rng: Optional[jax.Array] = None, train: bool = False,
                   noise_logits: Optional[jax.Array] = None,
                   n_valid_experts: Optional[int] = None) -> SelectionInfo:
    """Dispatch over the paper's selector variants.

    logits: (N, E_padded) = x @ W3.
    noise_logits: (N, E) = x @ W4, only for the Shazeer noisy-top-K variant.
    n_valid_experts: real expert count; experts >= this are padding (masked out).
    """
    n, e = logits.shape
    k = cfg.k
    neg = jnp.asarray(-1e9, logits.dtype)
    if n_valid_experts is not None and n_valid_experts < e:
        valid = jnp.arange(e) < n_valid_experts
        logits = jnp.where(valid[None, :], logits, neg)

    # Shazeer noisy gating (Eq. 13): add N(0,1)*softplus(W4 x) during training.
    if noise_logits is not None and train and rng is not None:
        rng, nrng = jax.random.split(rng)
        noise = jax.random.normal(nrng, logits.shape, logits.dtype)
        logits = logits + noise * jax.nn.softplus(noise_logits)

    probs = jax.nn.softmax(logits, axis=-1)            # Eq. 20 (regularizer input)

    act = cfg.selector_activation
    if act == "sigmoid":
        sel = jax.nn.sigmoid(logits)
    elif act in ("softmax", "softmax_pre_topk"):
        sel = probs
    else:
        raise ValueError(f"unknown selector activation {act}")

    # Expert dropout (sigma-MoE, Eq. 22): multiply sel by a per-expert mask.
    if train and cfg.expert_dropout > 0.0 and rng is not None:
        rng, drng = jax.random.split(rng)
        mask = expert_dropout_mask(drng, e, cfg.expert_dropout)
        sel = sel * mask[None, :].astype(sel.dtype)

    if act == "softmax_pre_topk" or (act == "softmax" and cfg.renormalize):
        # Footnote 4: renormalizing after top-K == top-K on logits before softmax.
        gates, idx = norm_topk(sel, k)
    else:
        gates, idx = jax.lax.top_k(sel, k)
        if cfg.renormalize:
            gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    return SelectionInfo(probs=probs, sel=sel, idx=idx, gates=gates)


def select_experts_sbase(logits: jax.Array, cfg: FFNConfig, *, train: bool = False,
                         n_valid_experts: Optional[int] = None) -> SelectionInfo:
    """S-BASE (Clark et al. 2022, as reimplemented by the paper Sec. 4).

    Training: Sinkhorn-balance the scores, route by the balanced matrix's top-K;
    weighting score is always sigmoid(logits) (Eq. 18). Eval: plain top-K of sigmoid.
    """
    n, e = logits.shape
    neg = jnp.asarray(-1e9, logits.dtype)
    if n_valid_experts is not None and n_valid_experts < e:
        valid = jnp.arange(e) < n_valid_experts
        logits = jnp.where(valid[None, :], logits, neg)
    sel = jax.nn.sigmoid(logits)
    probs = jax.nn.softmax(logits, axis=-1)
    if train:
        pi = sinkhorn(logits.astype(jnp.float32), cfg.sinkhorn_iters).astype(logits.dtype)
        if n_valid_experts is not None and n_valid_experts < e:
            pi = jnp.where((jnp.arange(e) < n_valid_experts)[None, :], pi, 0.0)
        _, idx = jax.lax.top_k(pi, cfg.k)
        gates = jnp.take_along_axis(sel, idx, axis=-1)
    else:
        gates, idx = jax.lax.top_k(sel, cfg.k)
    return SelectionInfo(probs=probs, sel=sel, idx=idx, gates=gates)
