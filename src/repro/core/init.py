"""Initialization schemes (paper Sec. 5, 'sigma-MoE Initialization').

The paper's insight: experts approximate a *single* dense MLP, so they must be
initialized exactly like the pre-layernorm dense baseline --

    W1 ~ N(0, sqrt(2 / (d_model * n_layers)))
    W2 ~ N(0, sqrt(2 / (d_ff    * n_layers)))

using the FULL d_ff (= G * N_E), *not* the per-expert group size G. The selector W3 is
drawn N(0,1), row-normalized to unit norm, then rescaled to W1's std so that only the
ANGLE between x and selector rows affects initial scores (footnote 5).

'standard init' (the ablation baseline) uses per-expert fan-in: W2 ~ N(0, sqrt(2/G)).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_std_in(d_model: int, n_layers: int) -> float:
    return math.sqrt(2.0 / (d_model * max(n_layers, 1)))


def dense_std_out(d_ff: int, n_layers: int) -> float:
    return math.sqrt(2.0 / (d_ff * max(n_layers, 1)))


def normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


def row_normalized(key, shape, std, dtype=jnp.float32):
    """N(0,1) -> rows rescaled to unit norm -> whole matrix rescaled to `std`.

    shape: (..., rows, cols); normalization is over the last axis.
    """
    w = jax.random.normal(key, shape, dtype)
    w = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-9)
    # After row normalization each entry has std ~ 1/sqrt(cols); rescale so the
    # elementwise std matches `std` (same as W1's rows).
    return w * (std * math.sqrt(shape[-1]))
