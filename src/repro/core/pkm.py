"""Product-Key Memories (paper Sec. 3.2, App. A.3; Lample et al. 2019).

Modifications made by the paper (which we follow):
  - no batch norm,
  - the input is sliced directly into two halves (no query projection),
  - same learning rate as the rest of the network,
  - ReLU (non-competitive) activation instead of softmax is the paper's improvement;
    both are available via cfg.activation,
  - optionally the paper's dense-equivalent init ('PKM + init' row of Tab. 6).

Framework lowering (paper Sec. 2 / core/dispatch.py): under the unified view a
PKM *is* an expert_size-1 MoE — the PEER heads of "Mixture of A Million
Experts" are exactly this. Retrieval (``pkm_select``) is the TWO-STAGE
product-key selection (``routing.two_stage_topk``): top-C per sub-key half,
the C*C candidate grid re-scored to the final top-K, so the full
(n_tokens, ns^2) score matrix never materializes and ``n_values = ns**2``
scales to 1M+ (ns=1024) at O(ns + C^2) per-token selection cost. C is
``cfg.pkm_candidates`` (``n_candidates`` knob, default K — the minimum width
for which the candidate grid provably contains the true top-K). The result
is a ``dispatch.Selection`` over the ns^2 value rows (vidx -> row ids,
w -> weights), and aggregation executes through the shared planned layer
(``dispatch.weighted_value_sum``): the value table stays in HBM, the
batch-wide selection union is deduplicated and value-index-sorted into an
``ops.DedupGatherPlan``, the compacted block streams HBM->VMEM once through
the run-batched row-DMA gather kernel, and a scatter-side indirection
(compacted slot -> (token, slot) weight) applies per-token weights. The
dense (N, H, K, d_model) value take + einsum survives only as the
``impl="dense"`` oracle reference (``_aggregate_dense``) and the einsum
fallback rung of the chain.

Key property (tested): applying top-C (C >= K) to u_a and u_b before the
Cartesian combine yields C^2 candidates that PROVABLY contain the true top-K
of the full u[i] = u_a[i mod sqrt(dff)] + u_b[i // sqrt(dff)].
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import FFNConfig
from . import init as initlib
from . import routing
from .dispatch import (Selection, base_aux, resolve_impl, selection_usage,
                       weighted_value_sum)


def init_pkm(key, d_model: int, cfg: FFNConfig, n_layers: int,
             dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    del ep_degree                      # uniform registry signature; PKM has no EP
    ka, kb, kv = jax.random.split(key, 3)
    h, ns = cfg.pkm_heads, cfg.n_subkeys
    half = d_model // 2
    # n_values is DERIVED from n_subkeys (cfg.n_values = ns**2, validated in
    # FFNConfig.validate): the value-table allocation and the paper's
    # dense-equivalent init std below always agree by construction.
    if cfg.sigma_moe_init:
        s_k = initlib.dense_std_in(d_model, n_layers)
        s_v = initlib.dense_std_out(cfg.n_values, n_layers)
    else:
        s_k = (d_model) ** -0.5
        s_v = (cfg.n_values) ** -0.5
    return {
        "keys_a": initlib.normal(ka, (h, half, ns), s_k, dtype),
        "keys_b": initlib.normal(kb, (h, half, ns), s_k, dtype),
        "values": initlib.normal(kv, (cfg.n_values, d_model), s_v, dtype),
    }


def pkm_select(params: Dict, xf: jax.Array, cfg: FFNConfig) -> Selection:
    """Product-key retrieval: the selection rule of the framework.

    Returns a Selection over the ns^2 value rows with S = H * K slots per
    token (idx (N, H*K), weights (N, H*K))."""
    h, ns, knn = cfg.pkm_heads, cfg.n_subkeys, cfg.pkm_knn
    xa, xb = jnp.split(xf, 2, axis=-1)                       # (N, d/2) each
    ua = jnp.einsum("nd,hds->nhs", xa, params["keys_a"].astype(xf.dtype))  # (N, H, ns)
    ub = jnp.einsum("nd,hds->nhs", xb, params["keys_b"].astype(xf.dtype))

    # Two-stage product-key selection (Eq. 8): top-C per half, re-score the
    # C*C candidate grid to the final top-K. Exact for C >= K (validated in
    # FFNConfig), and the full (N, ns^2) score matrix never exists — ns=1024
    # (n_values > 1M) costs the same per-token top-C as ns=8.
    top, sel_a, sel_b = routing.two_stage_topk(ua, ub, knn, cfg.pkm_candidates)
    # full index: i = i_b * ns + i_a  (u[i] = u_b[i // ns] + u_a[i mod ns], Eq. 8)
    vidx = sel_b * ns + sel_a                                # (N, H, K)

    if cfg.activation == "softmax":
        w = jax.nn.softmax(top, axis=-1)
    else:  # relu -- the paper's non-competitive choice
        w = jax.nn.relu(top)

    n = xf.shape[0]
    return Selection(idx=vidx.reshape(n, h * knn),
                     weights=w.reshape(n, h * knn), n_items=cfg.n_values)


def _aggregate_dense(values: jax.Array, sel: Selection) -> jax.Array:
    """impl="dense" oracle: the pre-refactor (N, S, d) take + einsum."""
    vals = values[sel.idx]                                   # (N, S, d)
    return jnp.einsum("ns,nsd->nd", sel.weights.astype(vals.dtype), vals)


def apply_pkm(params: Dict, x: jax.Array, cfg: FFNConfig, *,
              rng=None, train: bool = False,
              collect_stats: bool = False) -> Tuple[jax.Array, Dict]:
    del rng, train                     # uniform registry signature; PKM is static
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    sel = pkm_select(params, xf, cfg)
    values = params["values"].astype(x.dtype)
    if resolve_impl(cfg) == "dense":
        y = _aggregate_dense(values, sel)
    else:
        y = weighted_value_sum(values, sel, xf.shape[0], cfg)
    aux = base_aux()
    if collect_stats:
        aux["usage"] = selection_usage(sel)                  # value-usage histogram
    return y.reshape(*lead, d), aux


def pkm_full_scores(params: Dict, x: jax.Array, cfg: FFNConfig) -> jax.Array:
    """Oracle: the full u vector (N, H, ns*ns) -- for property tests only."""
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    xa, xb = jnp.split(xf, 2, axis=-1)
    ua = jnp.einsum("nd,hds->nhs", xa, params["keys_a"])
    ub = jnp.einsum("nd,hds->nhs", xb, params["keys_b"])
    ns = cfg.n_subkeys
    # u[i] = u_b[i // ns] + u_a[i mod ns]
    return (ub[..., :, None] + ua[..., None, :]).reshape(*ua.shape[:-1], ns * ns)
