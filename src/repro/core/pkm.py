"""Product-Key Memories (paper Sec. 3.2, App. A.3; Lample et al. 2019).

Modifications made by the paper (which we follow):
  - no batch norm,
  - the input is sliced directly into two halves (no query projection),
  - same learning rate as the rest of the network,
  - ReLU (non-competitive) activation instead of softmax is the paper's improvement;
    both are available via cfg.activation,
  - optionally the paper's dense-equivalent init ('PKM + init' row of Tab. 6).

Key property (tested): applying top-K to u_a and u_b before the Cartesian combine
yields K^2 candidates that PROVABLY contain the true top-K of the full
u[i] = u_a[i mod sqrt(dff)] + u_b[i // sqrt(dff)].
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import FFNConfig
from . import init as initlib


def init_pkm(key, d_model: int, cfg: FFNConfig, n_layers: int,
             dtype=jnp.float32) -> Dict:
    ka, kb, kv = jax.random.split(key, 3)
    h, ns = cfg.pkm_heads, cfg.n_subkeys
    half = d_model // 2
    if cfg.sigma_moe_init:
        s_k = initlib.dense_std_in(d_model, n_layers)
        s_v = initlib.dense_std_out(cfg.n_values, n_layers)
    else:
        s_k = (d_model) ** -0.5
        s_v = (cfg.n_values) ** -0.5
    return {
        "keys_a": initlib.normal(ka, (h, half, ns), s_k, dtype),
        "keys_b": initlib.normal(kb, (h, half, ns), s_k, dtype),
        "values": initlib.normal(kv, (ns * ns, d_model), s_v, dtype),
    }


def apply_pkm(params: Dict, x: jax.Array, cfg: FFNConfig) -> Tuple[jax.Array, Dict]:
    h, ns, knn = cfg.pkm_heads, cfg.n_subkeys, cfg.pkm_knn
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    xa, xb = jnp.split(xf, 2, axis=-1)                       # (N, d/2) each

    ua = jnp.einsum("nd,hds->nhs", xa, params["keys_a"].astype(x.dtype))  # (N, H, ns)
    ub = jnp.einsum("nd,hds->nhs", xb, params["keys_b"].astype(x.dtype))

    va, ia = jax.lax.top_k(ua, knn)                          # (N, H, K)
    vb, ib = jax.lax.top_k(ub, knn)

    # Cartesian combine (Eq. 8): scores s[i,j] = ua[i] + ub[j]; the true top-K of the
    # full u is guaranteed to be within these K^2 candidates.
    cand = va[..., :, None] + vb[..., None, :]               # (N, H, K, K)
    cand = cand.reshape(*cand.shape[:-2], knn * knn)
    top, flat = jax.lax.top_k(cand, knn)                     # (N, H, K)
    sel_a = jnp.take_along_axis(ia, flat // knn, axis=-1)    # index into u_a
    sel_b = jnp.take_along_axis(ib, flat % knn, axis=-1)
    # full index: i = i_b * ns + i_a  (u[i] = u_b[i // ns] + u_a[i mod ns], Eq. 8)
    vidx = sel_b * ns + sel_a                                # (N, H, K)

    if cfg.activation == "softmax":
        w = jax.nn.softmax(top, axis=-1)
    else:  # relu -- the paper's non-competitive choice
        w = jax.nn.relu(top)

    vals = params["values"].astype(x.dtype)[vidx]            # (N, H, K, d)
    y = jnp.einsum("nhk,nhkd->nd", w.astype(vals.dtype), vals)
    return y.reshape(*lead, d), {}


def pkm_full_scores(params: Dict, x: jax.Array, cfg: FFNConfig) -> jax.Array:
    """Oracle: the full u vector (N, H, ns*ns) -- for property tests only."""
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    xa, xb = jnp.split(xf, 2, axis=-1)
    ua = jnp.einsum("nd,hds->nhs", xa, params["keys_a"])
    ub = jnp.einsum("nd,hds->nhs", xb, params["keys_b"])
    ns = cfg.n_subkeys
    # u[i] = u_b[i // ns] + u_a[i mod ns]
    return (ub[..., :, None] + ua[..., None, :]).reshape(*ua.shape[:-1], ns * ns)
