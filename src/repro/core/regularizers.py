"""Load-balancing regularizers (paper Sec. 4-5).

Each takes the SelectionInfo of one MoE layer and returns a scalar loss (to be
*added*, already sign-correct for minimization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .routing import SelectionInfo


def entropy_reg(info: SelectionInfo, n_valid: int) -> jax.Array:
    """sigma-MoE (Eqs. 20-21): L = sum_e p[e] log p[e], p = batch-mean softmax.

    Minimizing L maximizes the entropy of the mean selection distribution.
    """
    p = jnp.mean(info.probs.astype(jnp.float32), axis=0)[:n_valid]
    return jnp.sum(p * jnp.log(p + 1e-9))


def switch_reg(info: SelectionInfo, n_valid: int) -> jax.Array:
    """Switch Transformer (Eqs. 15-17): L = N_E * f . p  with hard routing fraction f."""
    n, e = info.probs.shape
    k = info.idx.shape[-1]
    onehot = jax.nn.one_hot(info.idx, e, dtype=jnp.float32)       # (N, K, E)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)                 # (E,)
    p = jnp.mean(info.probs.astype(jnp.float32), axis=0)
    return n_valid * jnp.sum((f * p)[:n_valid]) / k


def cv_reg(info: SelectionInfo, n_valid: int) -> jax.Array:
    """Sparsely-Gated MoE (Eq. 14): CV^2 of total normalized-top-K importance."""
    n, e = info.probs.shape
    onehot = jax.nn.one_hot(info.idx, e, dtype=jnp.float32)
    imp = jnp.sum(onehot * info.gates.astype(jnp.float32)[..., None], axis=(0, 1))
    imp = imp[:n_valid]
    mean = jnp.mean(imp)
    var = jnp.var(imp)
    return var / (mean * mean + 1e-9)


REGULARIZERS = {"entropy": entropy_reg, "switch": switch_reg, "cv": cv_reg,
                "none": lambda info, n_valid: jnp.float32(0.0)}


def usage_stats(info: SelectionInfo, n_valid: int):
    """Diagnostics for expert-collapse analysis (paper Fig. 3/7)."""
    n, e = info.probs.shape
    onehot = jax.nn.one_hot(info.idx, e, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=(0, 1))[:n_valid]
    weight = jnp.sum(onehot * info.gates.astype(jnp.float32)[..., None],
                     axis=(0, 1))[:n_valid]
    frac = counts / (jnp.sum(counts) + 1e-9)
    ent = -jnp.sum(frac * jnp.log(frac + 1e-9))
    return {"counts": counts, "weight": weight, "usage_entropy": ent}
