"""Shared selection -> planned-execution layer: every approximator lowers here.

The paper's framework (Sec. 2) reads the two-layer MLP y = W2 act(W1 x) as a
keyed memory: u = act(W1 x) scores the d_ff rows of W2, and y is the u-weighted
sum of those rows. Every approximator is then a *selection rule* (which rows,
with what weight) plus the SAME execution primitive — a weighted aggregation of
the selected rows — and this module is that primitive. MoEs select whole
expert_size-row blocks and need the grouped GEMM; PKMs and the top-K MLP select
individual rows (an expert_size-1 MoE, exactly the PEER heads of "Mixture of A
Million Experts") and need only the retrieval + weighted sum. Both ride the
CVMM plan machinery built in kernels/ops.py.

Framework -> code map (paper Sec. 2-5)
--------------------------------------
===================  =============================  ===========================
paper                selection (core/)              execution (this module)
===================  =============================  ===========================
dense / GLU          all d_ff rows, weight u        dense matmul (topk_mlp.py)
  (Eq. 1-2)
top-K act (Sec 3.1)  lax.top_k over u               weighted_value_sum over
                       (topk_mlp.py)                  the K selected W2 rows
PKM (Sec 3.2)        product-key Cartesian top-k    weighted_value_sum over
                       (pkm.py -> vidx, w)            the H*K selected values
MoE (Sec 3.3-5)      router top-k                   expert_mlp: CvmmPlan
  sigma/switch/...     (routing.py SelectionInfo)     grouped GEMM (Eq. 11)
===================  =============================  ===========================

Kernel lowering — ONE capability chain instead of one per approximator
----------------------------------------------------------------------
``expert_mlp`` (dispatch="sort", the paper-faithful dropless path)
    pallas_fused   ops.moe_mlp_fused: gather + grouped GEMM + activation/GLU
                   + gate epilogues in-kernel (streamed HBM->VMEM row DMAs)
    pallas         ops.cvmm_planned x3 on one shared CvmmPlan
    ragged         jax.lax.ragged_dot (XLA grouped matmul; CPU default)
  plus the capacity paths: "einsum" (GShard/GSPMD) and "shard_map" (explicit
  all_to_all expert parallelism) — moved verbatim from core/moe.py.

``weighted_value_sum`` (PKM aggregation, top-K sparse down-projection)
    pallas_fused,  ops.gathered_weighted_sum_dedup: the batch's selection
    pallas         union is deduplicated + value-index-sorted into ONE
                   DedupGatherPlan, the compacted block streams HBM->VMEM
                   once (co-selected rows = one DMA, adjacent indices =
                   multi-row descriptors), per-token weights apply via the
                   scatter-side indirection (both rungs lower identically;
                   the names are kept for value_sum_path reporting)
    einsum         XLA take + einsum (materializes the (N, S, d) gather —
                   the reference semantics, kept as the last rung)

Per-layer selection of the chain entry point is ``FFNConfig.impl`` ("auto"
defers to ops.default_impl(): pallas_fused on TPU, ragged elsewhere); the
capability gates (``ops.fused_supported`` / ``ops.pallas_supported`` /
``ops.gather_supported``) degrade unsupported shapes down the chain instead
of failing at trace time. ``impl="dense"`` bypasses the planned layer
entirely (full down-projection / dense 4-D value gather) as the oracle
reference for tests and ablations.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from ..common import act_fn, cdiv, round_up
from ..configs.base import FFNConfig
from ..sharding.context import current_mesh
from .routing import SelectionInfo


# ---------------------------------------------------------------------------
# Selection contract
# ---------------------------------------------------------------------------

class Selection(NamedTuple):
    """The framework's selection contract: which rows of a value table each
    token selected and with what weight. Built on routing.SelectionInfo for
    MoEs (idx/gates over experts); PKM retrieval and the top-K mask produce
    the same shape over values / d_ff channels."""
    idx: jax.Array       # (N, S) int row ids
    weights: jax.Array   # (N, S) aggregation weights
    n_items: int         # static number of selectable rows (E / n_values / d_ff)


def base_aux() -> Dict[str, jax.Array]:
    """The uniform aux contract: every approximator returns at least these."""
    return {"moe_reg": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}


def selection_usage(sel: Selection) -> Dict[str, jax.Array]:
    """Usage histogram over the selected rows (experts / PKM values / top-K
    channels) for collapse analysis (paper Fig. 3/7) — scatter-based, so it
    stays cheap when n_items is large (PKM value tables)."""
    flat = sel.idx.reshape(-1)
    counts = jnp.zeros((sel.n_items,), jnp.float32).at[flat].add(1.0)
    weight = jnp.zeros((sel.n_items,), jnp.float32).at[flat].add(
        sel.weights.reshape(-1).astype(jnp.float32))
    frac = counts / (jnp.sum(counts) + 1e-9)
    ent = -jnp.sum(frac * jnp.log(frac + 1e-9))
    return {"counts": counts, "weight": weight, "usage_entropy": ent}


def resolve_impl(cfg: FFNConfig) -> str:
    """Per-layer impl knob: cfg.impl, with "auto" deferring to the global
    backend default (ops.default_impl / set_default_impl)."""
    from ..kernels import ops as kops
    return kops.default_impl() if cfg.impl == "auto" else cfg.impl


# ---------------------------------------------------------------------------
# Weighted value aggregation (PKM values / top-K W2 rows)
# ---------------------------------------------------------------------------

def dense_value_gather(values: jax.Array, idx: jax.Array) -> jax.Array:
    """The XLA-level dense value gather — materializes (N, S, d). Reference
    semantics of the einsum rung ONLY; the planned rungs must never call this
    (tripwire-tested in tests/test_core_dispatch.py)."""
    return values[idx]


def value_sum_path(cfg: FFNConfig, d_model: int, dtype=jnp.float32) -> str:
    """Which rung of the weighted-sum chain this config lowers to at this
    feature dim/dtype. The single source of the rung decision:
    ``weighted_value_sum`` executes whatever this answers (benchmarks call it
    directly for reporting)."""
    from ..kernels import ops as kops
    impl = resolve_impl(cfg)
    if impl == "dense":
        return "dense"
    if impl.startswith("pallas") and kops.gather_supported(d_model, dtype):
        return "pallas_fused" if impl.startswith("pallas_fused") else "pallas"
    return "einsum"


def weighted_value_sum(values: jax.Array, sel: Selection, n_tokens: int,
                       cfg: FFNConfig) -> jax.Array:
    """y[t] = sum_s sel.weights[t, s] * values[sel.idx[t, s]]  (N, d).

    The shared aggregation primitive: capability chain pallas_fused ->
    pallas -> einsum (see module docstring), resolved by ``value_sum_path``.
    The planned rungs build ONE DedupGatherPlan per call — the deduplicated,
    value-index-sorted union of the batch's selections — and stream the
    compacted row block HBM->VMEM once through the run-batched row-DMA
    pipeline (co-selected rows are one DMA, adjacent value indices pack into
    multi-row descriptors); per-token weights apply through the plan's
    scatter-side indirection. No (N, S, d) gather is materialized. ("dense"
    is handled by the approximators' own oracle references before calling
    here; it degrades to the einsum rung, which computes the identical
    quantity.)"""
    from ..kernels import ops as kops
    path = value_sum_path(cfg, values.shape[-1], values.dtype)
    if path in ("pallas_fused", "pallas"):
        plan = kops.make_dedup_gather_plan(sel.idx, sel.weights,
                                           values.shape[0])
        return kops.gathered_weighted_sum_dedup(
            values, plan, n_tokens,
            interpret=True if resolve_impl(cfg).endswith("_interpret")
            else None)
    rows = dense_value_gather(values, sel.idx)
    return jnp.einsum("ns,nsd->nd", sel.weights.astype(rows.dtype), rows)


# ---------------------------------------------------------------------------
# Expert MLP execution (MoE family) — moved from core/moe.py
# ---------------------------------------------------------------------------

def _expert_ffn(cfg: FFNConfig, h_pre, h_gate):
    act = act_fn(cfg.activation)
    u = act(h_pre)
    if cfg.glu_experts:
        u = u * h_gate
    return u


def _sort_path(params: Dict, xf: jax.Array, cfg: FFNConfig,
               info: SelectionInfo, e: int) -> jax.Array:
    """Dropless grouped matmul: the TPU CVMM path (paper Eq. 11).

    All pallas variants build ONE ``CvmmPlan`` per call (the layout metadata
    is shared by every kernel launch, forward and backward — kernels/ops.py).

    "pallas_fused": the gather, the w1 activation/GLU epilogue and the w2 gate
    multiply run inside the grouped-GEMM kernels; nothing between the routing
    and the final scatter-add is materialized at the XLA level. The gather
    streams rows HBM->VMEM through a double-buffered DMA pipeline, so
    ``fused_supported`` gates only on tile-level residency (activation
    fusibility + per-step tile working set) — production token counts no
    longer fall back to the unfused path.

    "pallas"/"ragged"/"ref": 1. flatten (token, k) pairs; 2. stable-argsort by
    expert id (the paper's CUDA kernel does exactly this reordering); 3.
    grouped matmul where row-groups share an expert matrix; 4. scatter-add
    results back per token, weighted by the gates.

    Under an active mesh the whole pipeline is pinned to REPLICATED: the
    grouped GEMMs here are not GSPMD-partitionable — ``jax.lax.ragged_dot``
    with expert-sharded weights silently returns wrong values (observed on
    jax 0.4.37: the partitioner slices the group dim without reconciling
    group_sizes), and the pallas custom calls can't be partitioned either.
    The sort path is the single-shard rung of the capability chain;
    "einsum" (GSPMD) and "shard_map" (explicit EP) are the sharded
    dispatches.
    """
    from ..kernels import ops as kops  # local import: kernels optional at import

    mesh = current_mesh()
    if mesh is not None:
        from jax.sharding import NamedSharding
        rep = NamedSharding(mesh, P())
        xf = jax.lax.with_sharding_constraint(xf, rep)
        info = info._replace(
            idx=jax.lax.with_sharding_constraint(info.idx, rep),
            gates=jax.lax.with_sharding_constraint(info.gates, rep))
        params = {name: (jax.lax.with_sharding_constraint(v, rep)
                         if name in ("we1", "we1g", "we2") else v)
                  for name, v in params.items()}

    n, d = xf.shape
    k = cfg.k
    impl = resolve_impl(cfg)
    if impl in ("einsum", "dense"):
        # value-sum-chain names have no meaning for the grouped GEMM: the
        # XLA-native rung of the sort path is the ragged grouped matmul.
        impl = "ragged"

    if impl.startswith("pallas"):
        # One resolution for the whole call: the rung of the capability chain
        # AND the tile choices come from the same tuner queries
        # (kernels/autotune.py), so "no tile fits" degradation and "which
        # tile" can never disagree. rung == "ragged" covers the old
        # pallas_supported() fallback: even the unfused kernels cannot tile
        # this d_model/expert_size into VMEM — use XLA's grouped matmul
        # instead of failing at trace time.
        kplan = kops.plan_sort_kernels(impl, d, cfg.expert_size,
                                       cfg.activation, xf.dtype,
                                       glu=cfg.glu_experts)
        if kplan.rung == "ragged":
            impl = "ragged"

    if impl.startswith("pallas"):
        w1 = params["we1"].astype(xf.dtype)
        w2 = params["we2"].astype(xf.dtype)
        w1g = params["we1g"].astype(xf.dtype) if cfg.glu_experts else None
        plan = kops.make_moe_plan(info.idx, info.gates, n, e)
        if kplan.rung == "pallas_fused":
            return kops.moe_mlp_fused(
                xf, plan, w1, w2, w1g, activation=cfg.activation,
                interpret=True if impl.endswith("_interpret") else None,
                tiles=kplan.fused)
        # unfused pallas: gather/sort at the XLA level, plan reused by all
        # three grouped GEMMs (and their backward) — no layout recompute.
        interpret = kops._impl_interpret(impl)
        src = jnp.repeat(jnp.arange(n), k)[plan.perm]     # sorted rows' tokens
        x_sorted = xf[src]                                # (N*K, d) gathered rows
        h = kops.cvmm_planned(x_sorted, plan, w1, interpret=interpret,
                              tiles=kplan.planned_w1)
        hg = (kops.cvmm_planned(x_sorted, plan, w1g, interpret=interpret,
                                tiles=kplan.planned_w1)
              if cfg.glu_experts else None)
        u = _expert_ffn(cfg, h, hg)
        y_sorted = kops.cvmm_planned(u, plan, w2, interpret=interpret,
                                     tiles=kplan.planned_w2)
        g_flat = info.gates.reshape(-1)
        y_sorted = y_sorted * g_flat[plan.perm][:, None].astype(y_sorted.dtype)
        out = jnp.zeros_like(xf)
        return out.at[src].add(y_sorted)

    e_flat = info.idx.reshape(-1)                         # (N*K,)
    g_flat = info.gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), k)

    perm = jnp.argsort(e_flat, stable=True)               # CVMM preprocessing sort
    e_sorted = e_flat[perm]
    x_sorted = xf[tok[perm]]                              # (N*K, d) gathered rows
    group_sizes = jnp.bincount(e_sorted, length=e)        # (E,)

    h = kops.cvmm(x_sorted, group_sizes, params["we1"].astype(xf.dtype),
                  impl=impl)
    if cfg.glu_experts:
        hg = kops.cvmm(x_sorted, group_sizes, params["we1g"].astype(xf.dtype),
                       impl=impl)
    else:
        hg = None
    u = _expert_ffn(cfg, h, hg)
    y_sorted = kops.cvmm(u, group_sizes, params["we2"].astype(xf.dtype),
                         impl=impl)
    y_sorted = y_sorted * g_flat[perm][:, None].astype(y_sorted.dtype)

    out = jnp.zeros_like(xf)
    out = out.at[tok[perm]].add(y_sorted)
    return out


# --- capacity (GShard) dispatch: einsum under pjit, shard_map explicit EP ---

def _capacity(n_tokens: int, k: int, e: int, factor: float, multiple: int = 8) -> int:
    return max(multiple, round_up(int(cdiv(n_tokens * k, e) * factor), multiple))


def _pack_capacity(xf, info: SelectionInfo, e: int, cap: int):
    """Scatter tokens into an (E, C, d) buffer. Returns buffer + combine metadata."""
    n, d = xf.shape
    k = info.idx.shape[-1]
    e_flat = info.idx.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)       # (NK, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1   # rank in expert
    keep = pos < cap
    tok = jnp.repeat(jnp.arange(n), k)
    e_safe = jnp.where(keep, e_flat, 0)
    p_safe = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[e_safe, p_safe].add(xf[tok] * keep[:, None].astype(xf.dtype),
                                     mode="drop")
    return buf, (tok, e_safe, p_safe, keep)


def _combine_capacity(buf_out, info: SelectionInfo, meta, n: int) -> jax.Array:
    tok, e_safe, p_safe, keep = meta
    g_flat = info.gates.reshape(-1)
    rows = buf_out[e_safe, p_safe]                            # (NK, d)
    rows = rows * (g_flat * keep.astype(g_flat.dtype))[:, None].astype(rows.dtype)
    out = jnp.zeros((n, buf_out.shape[-1]), buf_out.dtype)
    return out.at[tok].add(rows, mode="drop")


def _einsum_path(params: Dict, xf: jax.Array, cfg: FFNConfig,
                 info: SelectionInfo, e: int) -> Tuple[jax.Array, jax.Array]:
    n, d = xf.shape
    cap = _capacity(n, cfg.k, e, cfg.capacity_factor)
    buf, meta = _pack_capacity(xf, info, e, cap)
    # Constrain the buffer to expert-sharding so GSPMD materializes the dispatch
    # collective here rather than all-gathering the expert weights.
    if current_mesh() is not None:
        buf = jax.lax.with_sharding_constraint(
            buf, jax.sharding.NamedSharding(current_mesh(), P("model", None, None)))
    h = jnp.einsum("ecd,edg->ecg", buf, params["we1"].astype(xf.dtype))
    hg = (jnp.einsum("ecd,edg->ecg", buf, params["we1g"].astype(xf.dtype))
          if cfg.glu_experts else None)
    u = _expert_ffn(cfg, h, hg)
    buf_out = jnp.einsum("ecg,egd->ecd", u, params["we2"].astype(xf.dtype))
    if current_mesh() is not None:
        buf_out = jax.lax.with_sharding_constraint(
            buf_out, jax.sharding.NamedSharding(current_mesh(), P("model", None, None)))
    y = _combine_capacity(buf_out, info, meta, n)
    dropped = 1.0 - jnp.mean(meta[3].astype(jnp.float32))
    return y, dropped


def ep_local_plan(e_local: int, cap_g: int, n_experts_hint: int = 0):
    """The expert-sharded CvmmPlan one EP shard executes: after the dispatch
    all_to_all, a shard holds a DENSE (E/mp, C*mp, d) capacity buffer — every
    row's expert is known statically (row r belongs to expert r // cap_g), so
    the plan is input-independent and built once per (E/mp, C*mp) shape from
    concrete arrays (it closes over the shard_map body as constants). Riding
    ``make_moe_plan`` keeps EP on the same layout/chunk-table machinery as the
    dropless sort path, so ``ops.plan_dma_stats`` telemetry (descriptor
    counts, chunk_hist) stays meaningful under expert parallelism — and the
    plan-invariant pass (repro.analysis.plans) verifies the EP shard plans
    through this entry point, not a re-derivation."""
    from ..kernels import ops as kops
    n_rows = e_local * cap_g
    idx = jnp.repeat(jnp.arange(e_local, dtype=jnp.int32), cap_g)[:, None]
    gates = jnp.ones((n_rows, 1), jnp.float32)
    return kops.make_moe_plan(idx, gates, n_rows, e_local)


_ep_local_plan = ep_local_plan        # shard_map bodies predate the public name


def ep_plan_stats(cfg: FFNConfig, n_tokens: int, e: int, mesh) -> Dict:
    """Telemetry: DMA-descriptor stats of the CvmmPlan an EP shard runs for a
    given (token count, expert count, mesh). The EP buffer is fully
    contiguous, so the plan packs whole tiles into single descriptors —
    benchmarks/tests assert the batching factor survives under EP."""
    from ..kernels import ops as kops
    mp = mesh.shape["model"]
    n_shards = 1
    for a in mesh.axis_names:
        n_shards *= mesh.shape[a]
    cap = _capacity(n_tokens // n_shards, cfg.k, e, cfg.capacity_factor)
    e_local, cap_g = e // mp, cap * mp
    plan = ep_local_plan(e_local, cap_g)
    stats = kops.plan_dma_stats(plan, e_local * cap_g, verify=True)
    stats.update(e_local=e_local, capacity=cap, rows_per_shard=e_local * cap_g)
    return stats


def _ep_local_ffn(cfg: FFNConfig, buf: jax.Array, w1, w2, w1g):
    """One EP shard's expert FFN on its (E_local, C_g, d) dispatch buffer,
    lowered through the shared execution machinery: the planned/grouped CVMM
    (``ops.cvmm`` — pallas kernels or XLA ragged_dot, same capability chain as
    the sort path) instead of a bespoke einsum. ``impl="einsum"/"dense"``
    keeps the einsum as the reference rung."""
    from ..kernels import ops as kops
    impl = resolve_impl(cfg)
    e_local, cap_g, d = buf.shape
    if impl in ("einsum", "dense"):
        h = jnp.einsum("ecd,edg->ecg", buf, w1)
        hg = jnp.einsum("ecd,edg->ecg", buf, w1g) if w1g is not None else None
        u = _expert_ffn(cfg, h, hg)
        return jnp.einsum("ecg,egd->ecd", u, w2)
    rows = buf.reshape(e_local * cap_g, d)                 # expert-major: sorted
    group_sizes = jnp.full((e_local,), cap_g, jnp.int32)
    cvmm_impl = impl if impl.startswith("pallas") else "ragged"
    h = kops.cvmm(rows, group_sizes, w1, impl=cvmm_impl)
    hg = (kops.cvmm(rows, group_sizes, w1g, impl=cvmm_impl)
          if w1g is not None else None)
    u = _expert_ffn(cfg, h, hg)
    out = kops.cvmm(u, group_sizes, w2, impl=cvmm_impl)
    return out.reshape(e_local, cap_g, d)


def _shard_map_path(params: Dict, xf: jax.Array, cfg: FFNConfig,
                    info: SelectionInfo, e: int) -> Tuple[jax.Array, jax.Array]:
    """Explicit EP (GShard pattern), two-tier under a multi-host mesh: tokens
    sharded over EVERY mesh axis; expert weights sharded over 'model' — the
    intra-pod ICI axis — and REPLICATED over the DCN 'pod' axis (each pod
    holds a full expert copy; the pod tier carries data parallelism, and its
    gradient all-reduce is where optim/compress.py error-feedback compression
    engages — wired in runtime/steps.py).

    Per device: pack its token block into an (E, C, d) capacity buffer, one
    all_to_all along 'model' (split experts, concat capacity) -> (E/mp, C*mp, d),
    local FFN with the resident expert shard (through the planned CVMM
    machinery — ``_ep_local_ffn``), inverse all_to_all, local combine.
    Exactly 2 all_to_alls per MoE layer, both intra-pod — the
    collective-minimal dispatch that the einsum/GSPMD path only approximates
    (see EXPERIMENTS.md SPerf). Capacity overflow accounting (the dropped
    fraction) is pmean'd over the whole mesh so telemetry matches the global
    drop rate.
    """
    mesh = current_mesh()
    n, d = xf.shape
    if mesh is None or "model" not in mesh.axis_names:
        return _einsum_path(params, xf, cfg, info, e)
    mp = mesh.shape["model"]
    all_axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in all_axes:
        n_shards *= mesh.shape[a]
    if n % n_shards or e % mp or (n // n_shards) == 0:
        # token count or expert count not tileable (tiny decode batches):
        # fall back to the einsum path.
        return _einsum_path(params, xf, cfg, info, e)

    cap = _capacity(n // n_shards, cfg.k, e, cfg.capacity_factor)

    def local(xl, idxl, gatesl, w1, w2, w1g=None):
        # xl: (n_local, d); w1: (E/mp, d, g); w1g only present with GLU —
        # the non-GLU path neither ships nor multiplies a dummy gate weight.
        infol = SelectionInfo(probs=jnp.zeros((xl.shape[0], e), xl.dtype),
                              sel=jnp.zeros((xl.shape[0], e), xl.dtype),
                              idx=idxl, gates=gatesl)
        buf, meta = _pack_capacity(xl, infol, e, cap)          # (E, C, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)                   # (E/mp, C*mp, d)
        out = _ep_local_ffn(cfg, buf, w1, w2, w1g)             # (E/mp, C*mp, d)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)                   # (E, C, d)
        y = _combine_capacity(out, infol, meta, xl.shape[0])
        dropped = 1.0 - jnp.mean(meta[3].astype(jnp.float32))
        return y, jax.lax.pmean(dropped, all_axes)

    tok_spec = P(all_axes, None)
    w_spec = P("model", None, None)
    weights = (params["we1"].astype(xf.dtype), params["we2"].astype(xf.dtype))
    if cfg.glu_experts:
        weights += (params["we1g"].astype(xf.dtype),)
    y, dropped = _shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec,) * 3 + (w_spec,) * len(weights),
        out_specs=(tok_spec, P()),
    )(xf, info.idx, info.gates, *weights)
    return y, dropped


# Serving-layer decode fast path. The engine (repro.serving) installs a
# provider around its inference traces; when it claims a call (tiny-M
# decode/prefill-chunk shapes, "sort" dispatch) the expert MLP executes on a
# cached routing-free DecodePlan skeleton (kernels/ops.moe_mlp_decode)
# instead of rebuilding a CvmmPlan per step. The provider returns None to
# decline (wrong shape, no fitting tile, mesh active) and the normal chain
# runs. Forward-only: providers must never be left installed around
# training traces — install/uninstall via serving.Engine (context-managed).
_DECODE_PROVIDER = None


def set_decode_provider(fn) -> None:
    """Install (or with ``None`` remove) the decode fast-path provider:
    ``fn(params, xf, cfg, info, e) -> Optional[y]``."""
    global _DECODE_PROVIDER
    _DECODE_PROVIDER = fn


def expert_mlp(params: Dict, xf: jax.Array, cfg: FFNConfig,
               info: SelectionInfo, e: int) -> Tuple[jax.Array, jax.Array]:
    """Planned execution of one MoE layer's expert MLP at a fixed selection.

    Returns (y (N, d), dropped fraction). cfg.dispatch picks the dispatch
    strategy ("sort" = dropless CVMM, "einsum" = GShard capacity under pjit,
    "shard_map" = explicit all_to_all EP); the kernel chain within "sort" is
    resolved here (resolve_impl + capability gates), not by the caller."""
    if cfg.dispatch == "sort":
        if _DECODE_PROVIDER is not None:
            y = _DECODE_PROVIDER(params, xf, cfg, info, e)
            if y is not None:
                return y, jnp.float32(0.0)  # dropless, same as _sort_path
        return _sort_path(params, xf, cfg, info, e), jnp.float32(0.0)
    if cfg.dispatch == "shard_map":
        return _shard_map_path(params, xf, cfg, info, e)
    return _einsum_path(params, xf, cfg, info, e)
