"""sigma-MoE and baseline MoE variants (paper Sec. 3.3-5) with three dispatch paths.

Dispatch paths
--------------
"sort"      The paper-faithful, *dropless* path: tokens are argsorted by expert id and
            multiplied by their expert's matrices via a grouped matmul -- the TPU
            adaptation of the paper's CVMM CUDA kernel (kernels/cvmm.py). No capacity,
            no token drops, exactly Eq. 11. Experts live wherever the weights are
            sharded (replicated / FSDP); no all-to-all.

"einsum"    GShard-style capacity-based dense dispatch under plain pjit: scatter tokens
            into an (E, C, d) buffer, einsum against expert weights; GSPMD inserts the
            collectives when experts are sharded over the 'model' axis. Robust baseline
            for the multi-pod dry-run.

"shard_map" Explicit expert parallelism: per-data-shard routing + capacity packing,
            one all_to_all along 'model' to move token buffers to their expert shards,
            local expert FFN, inverse all_to_all back. The production EP path.

All paths share the routing math (routing.py), regularizers (regularizers.py) and the
paper's initialization (init.py), so ablations isolate exactly one design choice.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from ..common import act_fn, cdiv, round_up
from ..configs.base import FFNConfig
from ..sharding.context import current_mesh
from . import init as initlib
from .regularizers import REGULARIZERS, usage_stats
from .routing import SelectionInfo, select_experts, select_experts_sbase


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def n_experts_padded(cfg: FFNConfig, ep_degree: int = 0) -> int:
    if ep_degree and cfg.n_experts % ep_degree:
        return round_up(cfg.n_experts, ep_degree)
    return cfg.n_experts


def init_moe(key, d_model: int, cfg: FFNConfig, n_layers: int,
             dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    """Expert + selector parameters.

    sigma_moe_init=True (paper Sec. 5): W1/W2 stds use d_model/d_ff (the DENSE
    equivalent), W3 row-normalized at W1's std. False: 'standard init' ablation,
    std from per-expert fan-in G.
    """
    e = n_experts_padded(cfg, ep_degree)
    g = cfg.expert_size
    d_ff = cfg.n_experts * g
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    if cfg.sigma_moe_init:
        s1 = initlib.dense_std_in(d_model, n_layers)
        s2 = initlib.dense_std_out(d_ff, n_layers)
    else:
        s1 = (d_model) ** -0.5
        s2 = (0.1 / g) ** 0.5          # Switch Transformer's sqrt(0.1/G)
    p = {
        "we1": initlib.normal(k1, (e, d_model, g), s1, dtype),
        "we2": initlib.normal(k2, (e, g, d_model), s2, dtype),
        "router": initlib.row_normalized(k3, (cfg.n_experts, d_model), s1, dtype).T
              if cfg.sigma_moe_init else
              initlib.normal(k3, (d_model, cfg.n_experts), s1, dtype),
    }
    if cfg.glu_experts:
        p["we1g"] = initlib.normal(k4, (e, d_model, g), s1, dtype)
    if cfg.kind == "noisy_topk":
        p["router_noise"] = initlib.normal(k5, (d_model, cfg.n_experts), s1, dtype)
    if cfg.n_shared_experts:
        ks1, ks2, ks3 = jax.random.split(k6, 3)
        se = cfg.n_shared_experts
        p["shared_w1"] = initlib.normal(ks1, (se, d_model, g), s1, dtype)
        p["shared_w2"] = initlib.normal(ks2, (se, g, d_model), s2, dtype)
        if cfg.glu_experts:
            p["shared_w1g"] = initlib.normal(ks3, (se, d_model, g), s1, dtype)
    return p


def _expert_ffn(cfg: FFNConfig, h_pre, h_gate):
    act = act_fn(cfg.activation)
    u = act(h_pre)
    if cfg.glu_experts:
        u = u * h_gate
    return u


# ---------------------------------------------------------------------------
# Routing front-end (shared)
# ---------------------------------------------------------------------------

def _route(params: Dict, xf: jax.Array, cfg: FFNConfig, rng, train: bool,
           e_pad: int) -> SelectionInfo:
    logits = jnp.einsum("nd,de->ne", xf, params["router"].astype(xf.dtype))
    if e_pad > cfg.n_experts:
        pad = jnp.full((xf.shape[0], e_pad - cfg.n_experts), -1e9, logits.dtype)
        logits = jnp.concatenate([logits, pad], axis=-1)
    if cfg.kind == "sbase":
        return select_experts_sbase(logits, cfg, train=train,
                                    n_valid_experts=cfg.n_experts)
    noise_logits = None
    if cfg.kind == "noisy_topk":
        noise_logits = jnp.einsum("nd,de->ne", xf, params["router_noise"].astype(xf.dtype))
        if e_pad > cfg.n_experts:
            noise_logits = jnp.pad(noise_logits,
                                   ((0, 0), (0, e_pad - cfg.n_experts)))
    return select_experts(logits, cfg, rng=rng, train=train,
                          noise_logits=noise_logits, n_valid_experts=cfg.n_experts)


# ---------------------------------------------------------------------------
# Path 1: sort / CVMM (paper-faithful, dropless)
# ---------------------------------------------------------------------------

def _apply_sort(params: Dict, xf: jax.Array, cfg: FFNConfig, info: SelectionInfo,
                e: int) -> jax.Array:
    """Dropless grouped matmul: the TPU CVMM path.

    All pallas variants build ONE ``CvmmPlan`` per call (the layout metadata is
    shared by every kernel launch, forward and backward — kernels/ops.py).

    "pallas_fused": the gather, the w1 activation/GLU epilogue and the w2 gate
    multiply run inside the grouped-GEMM kernels; nothing between the routing
    and the final scatter-add is materialized at the XLA level. The gather
    streams rows HBM->VMEM through a double-buffered DMA pipeline, so
    ``fused_supported`` gates only on tile-level residency (activation
    fusibility + per-step tile working set) — production token counts no
    longer fall back to the unfused path.

    "pallas"/"ragged"/"ref": 1. flatten (token, k) pairs; 2. stable-argsort by
    expert id (the paper's CUDA kernel does exactly this reordering); 3. grouped
    matmul where row-groups share an expert matrix; 4. scatter-add results back
    per token, weighted by the gates.
    """
    from ..kernels import ops as kops  # local import: kernels are optional at import

    n, d = xf.shape
    k = cfg.k
    impl = kops.default_impl()

    if (impl.startswith("pallas")
            and not kops.pallas_supported(d, cfg.expert_size, xf.dtype)):
        # Even the unfused kernels cannot tile this d_model/expert_size into
        # VMEM (_pick_tn returns None and the kernels raise rather than
        # compile a VMEM-exhausting tn=128): fall back to XLA's grouped
        # matmul instead of failing at trace time.
        impl = "ragged"

    if impl.startswith("pallas"):
        w1 = params["we1"].astype(xf.dtype)
        w2 = params["we2"].astype(xf.dtype)
        w1g = params["we1g"].astype(xf.dtype) if cfg.glu_experts else None
        plan = kops.make_moe_plan(info.idx, info.gates, n, e)
        if (impl.startswith("pallas_fused")
                and kops.fused_supported(n, d, cfg.expert_size, cfg.activation,
                                         xf.dtype, glu=cfg.glu_experts)):
            return kops.moe_mlp_fused(
                xf, plan, w1, w2, w1g, activation=cfg.activation,
                interpret=True if impl.endswith("_interpret") else None)
        # unfused pallas: gather/sort at the XLA level, plan reused by all
        # three grouped GEMMs (and their backward) — no layout recompute.
        interpret = kops._impl_interpret(impl)
        src = jnp.repeat(jnp.arange(n), k)[plan.perm]     # sorted rows' tokens
        x_sorted = xf[src]                                # (N*K, d) gathered rows
        h = kops.cvmm_planned(x_sorted, plan, w1, interpret=interpret)
        hg = (kops.cvmm_planned(x_sorted, plan, w1g, interpret=interpret)
              if cfg.glu_experts else None)
        u = _expert_ffn(cfg, h, hg)
        y_sorted = kops.cvmm_planned(u, plan, w2, interpret=interpret)
        g_flat = info.gates.reshape(-1)
        y_sorted = y_sorted * g_flat[plan.perm][:, None].astype(y_sorted.dtype)
        out = jnp.zeros_like(xf)
        return out.at[src].add(y_sorted)

    e_flat = info.idx.reshape(-1)                         # (N*K,)
    g_flat = info.gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), k)

    perm = jnp.argsort(e_flat, stable=True)               # CVMM preprocessing sort
    e_sorted = e_flat[perm]
    x_sorted = xf[tok[perm]]                              # (N*K, d) gathered rows
    group_sizes = jnp.bincount(e_sorted, length=e)        # (E,)

    h = kops.cvmm(x_sorted, group_sizes, params["we1"].astype(xf.dtype),
                  impl=impl)
    if cfg.glu_experts:
        hg = kops.cvmm(x_sorted, group_sizes, params["we1g"].astype(xf.dtype),
                       impl=impl)
    else:
        hg = None
    u = _expert_ffn(cfg, h, hg)
    y_sorted = kops.cvmm(u, group_sizes, params["we2"].astype(xf.dtype),
                         impl=impl)
    y_sorted = y_sorted * g_flat[perm][:, None].astype(y_sorted.dtype)

    out = jnp.zeros_like(xf)
    out = out.at[tok[perm]].add(y_sorted)
    return out


# ---------------------------------------------------------------------------
# Path 2: einsum (GShard capacity dispatch, pure pjit)
# ---------------------------------------------------------------------------

def _capacity(n_tokens: int, k: int, e: int, factor: float, multiple: int = 8) -> int:
    return max(multiple, round_up(int(cdiv(n_tokens * k, e) * factor), multiple))


def _pack_capacity(xf, info: SelectionInfo, e: int, cap: int):
    """Scatter tokens into an (E, C, d) buffer. Returns buffer + combine metadata."""
    n, d = xf.shape
    k = info.idx.shape[-1]
    e_flat = info.idx.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)       # (NK, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1   # rank in expert
    keep = pos < cap
    tok = jnp.repeat(jnp.arange(n), k)
    e_safe = jnp.where(keep, e_flat, 0)
    p_safe = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[e_safe, p_safe].add(xf[tok] * keep[:, None].astype(xf.dtype),
                                     mode="drop")
    return buf, (tok, e_safe, p_safe, keep)


def _combine_capacity(buf_out, info: SelectionInfo, meta, n: int) -> jax.Array:
    tok, e_safe, p_safe, keep = meta
    g_flat = info.gates.reshape(-1)
    rows = buf_out[e_safe, p_safe]                            # (NK, d)
    rows = rows * (g_flat * keep.astype(g_flat.dtype))[:, None].astype(rows.dtype)
    out = jnp.zeros((n, buf_out.shape[-1]), buf_out.dtype)
    return out.at[tok].add(rows, mode="drop")


def _apply_einsum(params: Dict, xf: jax.Array, cfg: FFNConfig, info: SelectionInfo,
                  e: int) -> Tuple[jax.Array, jax.Array]:
    n, d = xf.shape
    cap = _capacity(n, cfg.k, e, cfg.capacity_factor)
    buf, meta = _pack_capacity(xf, info, e, cap)
    # Constrain the buffer to expert-sharding so GSPMD materializes the dispatch
    # collective here rather than all-gathering the expert weights.
    if current_mesh() is not None:
        buf = jax.lax.with_sharding_constraint(
            buf, jax.sharding.NamedSharding(current_mesh(), P("model", None, None)))
    h = jnp.einsum("ecd,edg->ecg", buf, params["we1"].astype(xf.dtype))
    hg = (jnp.einsum("ecd,edg->ecg", buf, params["we1g"].astype(xf.dtype))
          if cfg.glu_experts else None)
    u = _expert_ffn(cfg, h, hg)
    buf_out = jnp.einsum("ecg,egd->ecd", u, params["we2"].astype(xf.dtype))
    if current_mesh() is not None:
        buf_out = jax.lax.with_sharding_constraint(
            buf_out, jax.sharding.NamedSharding(current_mesh(), P("model", None, None)))
    y = _combine_capacity(buf_out, info, meta, n)
    dropped = 1.0 - jnp.mean(meta[3].astype(jnp.float32))
    return y, dropped


# ---------------------------------------------------------------------------
# Path 3: shard_map (explicit all_to_all expert parallelism)
# ---------------------------------------------------------------------------

def _apply_shard_map(params: Dict, xf: jax.Array, cfg: FFNConfig,
                     info: SelectionInfo, e: int) -> Tuple[jax.Array, jax.Array]:
    """Explicit EP (GShard pattern): tokens sharded over EVERY mesh axis; expert
    weights sharded over 'model'.

    Per device: pack its token block into an (E, C, d) capacity buffer, one
    all_to_all along 'model' (split experts, concat capacity) -> (E/mp, C*mp, d),
    local FFN with the resident expert shard, inverse all_to_all, local combine.
    Exactly 2 all_to_alls per MoE layer -- the collective-minimal dispatch that the
    einsum/GSPMD path only approximates (see EXPERIMENTS.md SPerf).
    """
    mesh = current_mesh()
    n, d = xf.shape
    if mesh is None or "model" not in mesh.axis_names:
        return _apply_einsum(params, xf, cfg, info, e)
    mp = mesh.shape["model"]
    all_axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in all_axes:
        n_shards *= mesh.shape[a]
    if n % n_shards or e % mp or (n // n_shards) == 0:
        # token count or expert count not tileable (tiny decode batches):
        # fall back to the einsum path.
        return _apply_einsum(params, xf, cfg, info, e)

    cap = _capacity(n // n_shards, cfg.k, e, cfg.capacity_factor)

    def local(xl, idxl, gatesl, w1, w2, w1g=None):
        # xl: (n_local, d); w1: (E/mp, d, g); w1g only present with GLU —
        # the non-GLU path neither ships nor multiplies a dummy gate weight.
        infol = SelectionInfo(probs=jnp.zeros((xl.shape[0], e), xl.dtype),
                              sel=jnp.zeros((xl.shape[0], e), xl.dtype),
                              idx=idxl, gates=gatesl)
        buf, meta = _pack_capacity(xl, infol, e, cap)          # (E, C, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)                   # (E/mp, C*mp, d)
        h = jnp.einsum("ecd,edg->ecg", buf, w1)
        hg = jnp.einsum("ecd,edg->ecg", buf, w1g) if w1g is not None else None
        u = _expert_ffn(cfg, h, hg)
        out = jnp.einsum("ecg,egd->ecd", u, w2)                # (E/mp, C*mp, d)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)                   # (E, C, d)
        y = _combine_capacity(out, infol, meta, xl.shape[0])
        dropped = 1.0 - jnp.mean(meta[3].astype(jnp.float32))
        return y, jax.lax.pmean(dropped, all_axes)

    tok_spec = P(all_axes, None)
    w_spec = P("model", None, None)
    weights = (params["we1"].astype(xf.dtype), params["we2"].astype(xf.dtype))
    if cfg.glu_experts:
        weights += (params["we1g"].astype(xf.dtype),)
    y, dropped = _shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec,) * 3 + (w_spec,) * len(weights),
        out_specs=(tok_spec, P()),
    )(xf, info.idx, info.gates, *weights)
    return y, dropped


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------

def apply_moe(params: Dict, x: jax.Array, cfg: FFNConfig, *,
              rng: Optional[jax.Array] = None, train: bool = False,
              collect_stats: bool = False) -> Tuple[jax.Array, Dict]:
    """y_hat = sum_{e in E_x} W2^e s[e] act(W1^e x)   (paper Eq. 11) + aux losses."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    e = params["we1"].shape[0]                             # possibly padded

    info = _route(params, xf, cfg, rng, train, e)

    dropped = jnp.float32(0.0)
    if cfg.dispatch == "sort":
        y = _apply_sort(params, xf, cfg, info, e)
    elif cfg.dispatch == "shard_map":
        y, dropped = _apply_shard_map(params, xf, cfg, info, e)
    else:
        y, dropped = _apply_einsum(params, xf, cfg, info, e)

    if cfg.n_shared_experts:
        act = act_fn(cfg.activation)
        hs = jnp.einsum("nd,edg->eng", xf, params["shared_w1"].astype(xf.dtype))
        us = act(hs)
        if cfg.glu_experts:
            us = us * jnp.einsum("nd,edg->eng", xf,
                                 params["shared_w1g"].astype(xf.dtype))
        y = y + jnp.einsum("eng,egd->nd", us, params["shared_w2"].astype(xf.dtype))

    reg = REGULARIZERS[cfg.reg_kind](info, cfg.n_experts)
    aux = {"moe_reg": cfg.reg_gamma * reg, "moe_dropped": dropped}
    if collect_stats:
        aux["usage"] = usage_stats(info, cfg.n_experts)
    return y.reshape(*lead, d), aux
