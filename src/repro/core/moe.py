"""sigma-MoE and baseline MoE variants (paper Sec. 3.3-5): parameters + routing.

This module owns what is MoE-*specific* — expert/selector initialization
(paper Sec. 5 init), the routing front-end (routing.py selectors at the
layer's logits), shared always-on experts, and the regularizer bookkeeping.
The selection -> dispatch -> execution machinery lives in core/dispatch.py
(``dispatch.expert_mlp``), shared with every other approximator in the
paper's framework: the three dispatch paths ("sort" dropless CVMM, "einsum"
GShard capacity under pjit, "shard_map" explicit all_to_all EP) and the
kernel capability chain (pallas_fused -> pallas -> ragged) are resolved
there, in one place. ``apply_moe`` is routing + one call into that layer.

All paths share the routing math (routing.py), regularizers (regularizers.py)
and the paper's initialization (init.py), so ablations isolate exactly one
design choice.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..common import act_fn, round_up
from ..configs.base import FFNConfig
from . import init as initlib
from .dispatch import expert_mlp
from .regularizers import REGULARIZERS, usage_stats
from .routing import SelectionInfo, select_experts, select_experts_sbase


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def n_experts_padded(cfg: FFNConfig, ep_degree: int = 0) -> int:
    if ep_degree and cfg.n_experts % ep_degree:
        return round_up(cfg.n_experts, ep_degree)
    return cfg.n_experts


def init_moe(key, d_model: int, cfg: FFNConfig, n_layers: int,
             dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    """Expert + selector parameters.

    sigma_moe_init=True (paper Sec. 5): W1/W2 stds use d_model/d_ff (the DENSE
    equivalent), W3 row-normalized at W1's std. False: 'standard init' ablation,
    std from per-expert fan-in G.
    """
    e = n_experts_padded(cfg, ep_degree)
    g = cfg.expert_size
    d_ff = cfg.n_experts * g
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    if cfg.sigma_moe_init:
        s1 = initlib.dense_std_in(d_model, n_layers)
        s2 = initlib.dense_std_out(d_ff, n_layers)
    else:
        s1 = (d_model) ** -0.5
        s2 = (0.1 / g) ** 0.5          # Switch Transformer's sqrt(0.1/G)
    p = {
        "we1": initlib.normal(k1, (e, d_model, g), s1, dtype),
        "we2": initlib.normal(k2, (e, g, d_model), s2, dtype),
        "router": initlib.row_normalized(k3, (cfg.n_experts, d_model), s1, dtype).T
              if cfg.sigma_moe_init else
              initlib.normal(k3, (d_model, cfg.n_experts), s1, dtype),
    }
    if cfg.glu_experts:
        p["we1g"] = initlib.normal(k4, (e, d_model, g), s1, dtype)
    if cfg.kind == "noisy_topk":
        p["router_noise"] = initlib.normal(k5, (d_model, cfg.n_experts), s1, dtype)
    if cfg.n_shared_experts:
        ks1, ks2, ks3 = jax.random.split(k6, 3)
        se = cfg.n_shared_experts
        p["shared_w1"] = initlib.normal(ks1, (se, d_model, g), s1, dtype)
        p["shared_w2"] = initlib.normal(ks2, (se, g, d_model), s2, dtype)
        if cfg.glu_experts:
            p["shared_w1g"] = initlib.normal(ks3, (se, d_model, g), s1, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing front-end (shared by all dispatch paths)
# ---------------------------------------------------------------------------

def _route(params: Dict, xf: jax.Array, cfg: FFNConfig, rng, train: bool,
           e_pad: int) -> SelectionInfo:
    logits = jnp.einsum("nd,de->ne", xf, params["router"].astype(xf.dtype))
    if e_pad > cfg.n_experts:
        pad = jnp.full((xf.shape[0], e_pad - cfg.n_experts), -1e9, logits.dtype)
        logits = jnp.concatenate([logits, pad], axis=-1)
    if cfg.kind == "sbase":
        return select_experts_sbase(logits, cfg, train=train,
                                    n_valid_experts=cfg.n_experts)
    noise_logits = None
    if cfg.kind == "noisy_topk":
        noise_logits = jnp.einsum("nd,de->ne", xf, params["router_noise"].astype(xf.dtype))
        if e_pad > cfg.n_experts:
            noise_logits = jnp.pad(noise_logits,
                                   ((0, 0), (0, e_pad - cfg.n_experts)))
    return select_experts(logits, cfg, rng=rng, train=train,
                          noise_logits=noise_logits, n_valid_experts=cfg.n_experts)


# ---------------------------------------------------------------------------
# Public apply: routing + the shared execution layer
# ---------------------------------------------------------------------------

def apply_moe(params: Dict, x: jax.Array, cfg: FFNConfig, *,
              rng: Optional[jax.Array] = None, train: bool = False,
              collect_stats: bool = False) -> Tuple[jax.Array, Dict]:
    """y_hat = sum_{e in E_x} W2^e s[e] act(W1^e x)   (paper Eq. 11) + aux losses."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    e = params["we1"].shape[0]                             # possibly padded

    info = _route(params, xf, cfg, rng, train, e)
    y, dropped = expert_mlp(params, xf, cfg, info, e)

    if cfg.n_shared_experts:
        act = act_fn(cfg.activation)
        hs = jnp.einsum("nd,edg->eng", xf, params["shared_w1"].astype(xf.dtype))
        us = act(hs)
        if cfg.glu_experts:
            us = us * jnp.einsum("nd,edg->eng", xf,
                                 params["shared_w1g"].astype(xf.dtype))
        y = y + jnp.einsum("eng,egd->nd", us, params["shared_w2"].astype(xf.dtype))

    reg = REGULARIZERS[cfg.reg_kind](info, cfg.n_experts)
    aux = {"moe_reg": cfg.reg_gamma * reg, "moe_dropped": dropped}
    if collect_stats:
        aux["usage"] = usage_stats(info, cfg.n_experts)
    return y.reshape(*lead, d), aux
