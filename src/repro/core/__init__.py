"""The paper's core contribution: approximations of 2-layer MLPs.

- dispatch:  the shared selection -> planned-execution layer (Sec. 2 framework)
- topk_mlp:  dense / GLU / Top-K activation (Sec. 2, 3.1)
- pkm:       product-key memories (Sec. 3.2)
- moe:       sigma-MoE + Switch / S-BASE / noisy-top-K baselines (Sec. 3.3-5)
"""
from .dispatch import (Selection, base_aux, expert_mlp, resolve_impl,
                       selection_usage, value_sum_path, weighted_value_sum)
from .moe import apply_moe, init_moe, n_experts_padded
from .pkm import apply_pkm, init_pkm, pkm_full_scores, pkm_select
from .routing import (SelectionInfo, expert_dropout_mask, norm_topk,
                      select_experts, select_experts_sbase, sinkhorn)
from .regularizers import REGULARIZERS, cv_reg, entropy_reg, switch_reg, usage_stats
from .topk_mlp import apply_dense, init_dense

__all__ = [
    "Selection", "base_aux", "expert_mlp", "resolve_impl",
    "selection_usage", "value_sum_path", "weighted_value_sum",
    "apply_moe", "init_moe", "n_experts_padded", "apply_pkm", "init_pkm",
    "pkm_full_scores", "pkm_select", "SelectionInfo", "expert_dropout_mask",
    "norm_topk", "select_experts", "select_experts_sbase", "sinkhorn",
    "REGULARIZERS", "cv_reg", "entropy_reg", "switch_reg", "usage_stats",
    "apply_dense", "init_dense",
]
