from .adamw import adamw_init, adamw_update, OptState
from .schedule import make_schedule
from .compress import (EXPERT_PARAM_NAMES, compress_grads, compress_pod_grads,
                       init_compression_state, is_expert_leaf)
from .clip import clip_by_global_norm, global_norm

__all__ = ["adamw_init", "adamw_update", "OptState", "make_schedule",
           "compress_grads", "compress_pod_grads", "init_compression_state",
           "is_expert_leaf", "EXPERT_PARAM_NAMES", "clip_by_global_norm",
           "global_norm"]
