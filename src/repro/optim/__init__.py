from .adamw import adamw_init, adamw_update, OptState
from .schedule import make_schedule
from .compress import compress_grads, init_compression_state
from .clip import clip_by_global_norm, global_norm

__all__ = ["adamw_init", "adamw_update", "OptState", "make_schedule",
           "compress_grads", "init_compression_state", "clip_by_global_norm",
           "global_norm"]
