"""AdamW with fp32 master weights (mixed-precision training).

Params live in fp32 (master); compute casts to bf16 at use. m/v are fp32 and inherit
the parameter sharding (FSDP shards optimizer state for free under GSPMD).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> OptState:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def adamw_update(grads, state: OptState, params, cfg: OptimizerConfig,
                 lr: jax.Array):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat, treedef = jax.tree_util.tree_flatten(params)
    gflat = treedef.flatten_up_to(grads)
    mflat = treedef.flatten_up_to(state.mu)
    vflat = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v)
