"""LR schedules: cosine (the paper's: 2.5e-4 -> 0 over 100k), WSD (minicpm) and
constant, all with linear warmup."""
from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import OptimizerConfig


def make_schedule(cfg: OptimizerConfig):
    base = cfg.lr
    warm = max(cfg.warmup_steps, 0)
    total = max(cfg.total_steps, 1)

    def sched(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm_lr = base * jnp.minimum(s / jnp.maximum(warm, 1), 1.0)
        t = jnp.clip((s - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            lr = base * (cfg.final_lr_ratio +
                         (1 - cfg.final_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        elif cfg.schedule == "wsd":
            # warmup-stable-decay: stable until 90%, then linear decay.
            decay_frac = jnp.clip((t - 0.9) / 0.1, 0.0, 1.0)
            lr = base * (1.0 - (1.0 - cfg.final_lr_ratio) * decay_frac)
        else:
            lr = jnp.float32(base)
        return jnp.where(s < warm, warm_lr, lr)

    return sched
