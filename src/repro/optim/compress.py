"""Error-feedback gradient compression for the cross-pod (DCN) all-reduce.

At 512+ chips the pod axis crosses data-center network, not ICI; compressing the
gradient exchanged there is a standard distributed-optimization trick. We implement
compress -> (wire) -> decompress with *error feedback*: the quantization residual is
added back into the next step's gradient, which keeps SGD/Adam convergence
(Karimireddy et al. 2019).

Modes: "bf16" (cast), "int8" (per-tensor absmax scale). The compressed representation
is what a DCN-aware collective would put on the wire.

Two wirings:

``compress_grads``
    The single-host roundtrip on the fully reduced gradient (legacy path, kept
    for meshes without a 'pod' axis): one shared error state, applied before
    the optimizer.

``compress_pod_grads``
    The multi-host wiring (runtime/steps.py engages it whenever the mesh has a
    'pod' axis of size > 1 and compression is on). Input gradients carry a
    leading per-pod dimension — pod p's slice is its PARTIAL gradient, the
    contribution of its local batch shard BEFORE the cross-pod reduction.
    Each pod adds its own residual, quantizes, and what crosses the pod axis
    (the mean over the leading dim, which the partitioner lowers to the DCN
    all-reduce once the stacked grads are sharded over 'pod') is exactly the
    compressed wire values. Error state is per-pod: leading dim pod_size,
    sharded over the 'pod' mesh axis (sharding/logical.py 'pod_err' rule).
    Only the expert-parameter subtree (``EXPERT_PARAM_NAMES`` leaves — the
    bulk of an expert-parallel model's gradient bytes) is compressed; every
    other leaf takes the exact all-reduce and keeps a placeholder residual.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

# The expert-parameter subtree: the sparse-FFN tables that dominate gradient
# bytes under expert parallelism. Dense trunk params (attention, norms,
# embeddings, routers) keep the exact DCN all-reduce.
EXPERT_PARAM_NAMES = frozenset(
    {"we1", "we1g", "we2", "keys_a", "keys_b", "values"})


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None) or getattr(entry, "name", None)
        if isinstance(key, str):
            return key
    return ""


def is_expert_leaf(path) -> bool:
    return _leaf_name(path) in EXPERT_PARAM_NAMES


def init_compression_state(params, pod: int = 1):
    """Error-feedback residuals. pod <= 1: one params-shaped residual per leaf
    (legacy whole-tree roundtrip). pod > 1: per-pod residuals with a leading
    pod dim on the EXPERT leaves (each pod's quantization error is its own);
    non-compressed leaves hold a (1,) placeholder so the state tree structure
    stays checkpoint-stable."""
    if pod <= 1:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return jax.tree_util.tree_map_with_path(
        lambda path, p: (jnp.zeros((pod,) + p.shape, jnp.float32)
                         if is_expert_leaf(path) else jnp.zeros((1,), jnp.float32)),
        params)


def _roundtrip(g: jax.Array, mode: str) -> jax.Array:
    if mode == "bf16":
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale
    raise ValueError(mode)


def _roundtrip_stacked(g: jax.Array, mode: str) -> jax.Array:
    """Per-pod roundtrip on a (pod, ...) stack: each pod quantizes its own
    slice (per-slice absmax scale for int8 — pods see different partials)."""
    if mode == "bf16":
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        axes = tuple(range(1, g.ndim))
        scale = jnp.maximum(jnp.max(jnp.abs(g), axis=axes, keepdims=True),
                            1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale
    raise ValueError(mode)


def compress_grads(grads, err_state, mode: str) -> Tuple[Any, Any]:
    """Returns (decompressed grads as seen after the wire, new error state)."""
    if mode == "none":
        return grads, err_state

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        wire = _roundtrip(gf, mode)
        return wire.astype(g.dtype), gf - wire

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out]))


def compress_pod_grads(pod_grads, err_state, mode: str) -> Tuple[Any, Any]:
    """Cross-pod reduction with compressed expert gradients.

    ``pod_grads``: pytree whose leaves are (pod, *param_shape) PARTIAL
    gradients (one slice per pod, pre-reduction). ``err_state``: matching
    per-pod residuals from ``init_compression_state(params, pod=...)``.

    Expert leaves: wire_p = Q(g_p + e_p) per pod, reduced = mean_p wire_p,
    new residual e_p = (g_p + e_p) - wire_p. Other leaves: exact mean, and
    the placeholder residual passes through. Returns (reduced grads — no
    leading pod dim — in the input dtype, new error state)."""
    if mode == "none":
        return (jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0)
                                       .astype(g.dtype), pod_grads), err_state)

    flat_g, treedef = jax.tree_util.tree_flatten_with_path(pod_grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs, errs = [], []
    for (path, g), e in zip(flat_g, flat_e):
        if is_expert_leaf(path):
            gf = g.astype(jnp.float32) + e
            wire = _roundtrip_stacked(gf, mode)
            outs.append(jnp.mean(wire, axis=0).astype(g.dtype))
            errs.append(gf - wire)
        else:
            outs.append(jnp.mean(g, axis=0).astype(g.dtype))
            errs.append(e)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, errs))
