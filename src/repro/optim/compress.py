"""Error-feedback gradient compression for the cross-pod (DCN) all-reduce.

At 512+ chips the pod axis crosses data-center network, not ICI; compressing the
gradient exchanged there is a standard distributed-optimization trick. We implement
compress -> (wire) -> decompress with *error feedback*: the quantization residual is
added back into the next step's gradient, which keeps SGD/Adam convergence
(Karimireddy et al. 2019).

Modes: "bf16" (cast), "int8" (per-tensor absmax scale). The compressed representation
is what a DCN-aware collective would put on the wire; under single-program SPMD we
apply it before the optimizer so the numerics match the deployed system.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_compression_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _roundtrip(g: jax.Array, mode: str) -> jax.Array:
    if mode == "bf16":
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale
    raise ValueError(mode)


def compress_grads(grads, err_state, mode: str) -> Tuple[Any, Any]:
    """Returns (decompressed grads as seen after the wire, new error state)."""
    if mode == "none":
        return grads, err_state

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        wire = _roundtrip(gf, mode)
        return wire.astype(g.dtype), gf - wire

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out]))
