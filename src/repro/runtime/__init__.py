from .loss import chunked_cross_entropy

__all__ = ["chunked_cross_entropy"]
