"""Straggler / health monitoring for long-running multi-pod jobs.

StragglerMonitor keeps an EWMA of step wall-time and flags outliers (a slow host,
failing HBM, thermal throttling). On a real deployment the `on_straggler` callback
feeds the cluster orchestrator (evict + restore-from-checkpoint on a hot spare); here
it logs and counts, and the fault-tolerant loop (launch/train.py) exercises the same
restart path via checkpoint restore.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ewma: float = 0.9,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.ewma_coef = ewma
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.mean: Optional[float] = None
        self.count = 0
        self.flagged: List[int] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.count += 1
        if self.mean is None:
            self.mean = dt
        if self.count > self.warmup and dt > self.threshold * self.mean:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self.mean)
        else:
            self.mean = self.ewma_coef * self.mean + (1 - self.ewma_coef) * dt
        return dt
