"""Step builders: jit-able train / prefill / decode steps with mixed precision,
gradient accumulation, clipping, compression and LR scheduling baked in.

The returned functions are pure (state, batch, rng) -> (state, metrics) and carry
*all* mutable training state in one pytree, so checkpointing and restart are exact.

Pod-tier gradient compression (multi-host wiring)
-------------------------------------------------
When ``make_train_step`` is given a mesh with a 'pod' axis of size > 1 and
``grad_compression != "none"``, the step stops treating compression as a
host-local roundtrip on the reduced gradient and instead wires it into the
cross-pod (DCN) reduction itself:

  1. the global batch is split along the 'pod' axis and each pod slice's
     PARTIAL gradient is computed separately (a scan over pod slices — the
     same microbatching machinery as grad_accum, so the two compose: each
     pod slice still microbatches internally);
  2. the stacked (pod, ...) partials are sharding-constrained onto the 'pod'
     mesh axis, so the ONLY cross-pod gradient traffic in the compiled
     program is the mean over that leading dim;
  3. that mean goes through ``optim.compress_pod_grads``: expert-parameter
     leaves are int8/bf16 error-feedback quantized PER POD before the
     reduction (the wire values are what crosses DCN), dense trunk leaves
     take the exact mean. Residuals are per-pod ((pod, ...) 'err' leaves,
     sharded over 'pod' by the 'pod_err' logical rule).

Ordering note: the legacy path clips then compresses the reduced gradient;
the pod path necessarily compresses DURING the reduction and clips after —
clipping a not-yet-reduced partial would need a second cross-pod collective
for the global norm. XL memory (``xl_memory``) is not supported on the pod
path (its state is batch-minor); request one or the other.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import OptimizerConfig
from ..models.lm import LM
from ..optim import (adamw_init, adamw_update, clip_by_global_norm, compress_grads,
                     compress_pod_grads, init_compression_state, make_schedule)


def _pod_size(mesh) -> int:
    if mesh is None or "pod" not in getattr(mesh, "axis_names", ()):
        return 1
    return mesh.shape["pod"]


def init_train_state(model: LM, key, opt_cfg: OptimizerConfig,
                     use_mems: bool = False, batch: int = 0,
                     pod: int = 1) -> Dict[str, Any]:
    """``pod``: size of the mesh's DCN 'pod' axis (1 = no pod tier). With
    pod > 1 and compression on, the error-feedback state is per-pod (leading
    pod dim on expert leaves — see module header)."""
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    if opt_cfg.grad_compression != "none":
        state["err"] = init_compression_state(params, pod=pod)
    if use_mems and model.cfg.xl_memory:
        from ..models.stack import init_mems
        state["mems"] = init_mems(model.cfg, batch, model.dtype)
    return state


def make_train_step(model: LM, opt_cfg: OptimizerConfig,
                    grad_accum: int = 1, mesh=None):
    sched = make_schedule(opt_cfg)
    use_mems = bool(model.cfg.xl_memory)
    pod = _pod_size(mesh)
    pod_tier = pod > 1 and opt_cfg.grad_compression != "none"
    if pod_tier and use_mems:
        raise NotImplementedError(
            "pod-tier gradient compression does not support xl_memory "
            "(mems state is batch-minor; slicing it per pod is unsupported)")

    def loss_for(params, batch, rng, mems):
        out = model.loss(params, batch, rng=rng, train=True, mems=mems)
        loss, aux = out
        if use_mems:
            metrics, new_mems = aux
        else:
            metrics, new_mems = aux, None
        return loss, (metrics, new_mems)

    def compute_grads(params, batch, rng, mems):
        if grad_accum <= 1:
            (loss, (metrics, new_mems)), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch, rng, mems)
            return loss, metrics, new_mems, grads

        # microbatching: scan over grad_accum slices, accumulate fp32 grads.
        def micro(carry, xs):
            acc, mems_c = carry
            mb, r = xs
            (loss, (metrics, new_mems)), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, mb, r, mems_c)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum, acc, grads)
            return (acc, new_mems if use_mems else mems_c), (loss, metrics)

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
            batch)
        rngs = jax.random.split(rng, grad_accum)
        (grads, new_mems), (losses, metricss) = jax.lax.scan(
            micro, (zeros, mems), (mbs, rngs))
        loss = jnp.mean(losses)
        metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m, 0), metricss)
        return loss, metrics, (new_mems if use_mems else None), grads

    def pod_partial_grads(params, batch, rng):
        """Per-pod partial gradients: scan over pod slices of the batch, each
        slice running the full compute_grads (grad_accum microbatching and the
        MoE dispatch path — including the EP shard_map — compose unchanged).
        Returns (loss, metrics, stacked (pod, ...) grads) with the stack
        sharding-constrained onto the 'pod' mesh axis so the downstream mean
        is the cross-pod all-reduce."""
        def one_pod(_, xs):
            mb, r = xs
            loss, metrics, _, grads = compute_grads(params, mb, r, None)
            return None, (loss, metrics, grads)

        b = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if b % pod:
            raise ValueError(f"pod-tier compression needs the global batch "
                             f"({b}) divisible by the pod axis ({pod})")
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((pod, x.shape[0] // pod) + x.shape[1:]), batch)
        rngs = jax.random.split(rng, pod)
        _, (losses, metricss, grads_pp) = jax.lax.scan(
            one_pod, None, (mbs, rngs))
        if mesh is not None:
            pod_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("pod"))
            grads_pp = jax.tree_util.tree_map(
                lambda g: jax.lax.with_sharding_constraint(g, pod_sh), grads_pp)
        loss = jnp.mean(losses)
        metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m, 0), metricss)
        return loss, metrics, grads_pp

    def train_step(state: Dict[str, Any], batch: Dict, rng) -> Tuple[Dict, Dict]:
        params = state["params"]
        mems = state.get("mems")
        rng = jax.random.fold_in(rng, state["opt"].step)
        new_state = dict(state)
        if pod_tier:
            # Multi-host wiring: compress the expert subtree INSIDE the
            # cross-pod reduction (see module header), then clip the reduced
            # gradient.
            loss, metrics, grads_pp = pod_partial_grads(params, batch, rng)
            grads, new_err = compress_pod_grads(grads_pp, state["err"],
                                                opt_cfg.grad_compression)
            new_state["err"] = new_err
            grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
            new_mems = None
        else:
            loss, metrics, new_mems, grads = compute_grads(params, batch, rng,
                                                           mems)
            grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
            if "err" in state:
                grads, new_err = compress_grads(grads, state["err"],
                                                opt_cfg.grad_compression)
                new_state["err"] = new_err
        lr = sched(state["opt"].step)
        new_params, new_opt = adamw_update(grads, state["opt"], params, opt_cfg, lr)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        if new_mems is not None:
            new_state["mems"] = new_mems
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def make_eval_step(model: LM):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, rng=None, train=False)
        return loss, metrics
    return eval_step


def make_prefill_step(model: LM, max_len: int):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: LM):
    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return decode_step
