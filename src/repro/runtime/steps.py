"""Step builders: jit-able train / prefill / decode steps with mixed precision,
gradient accumulation, clipping, compression and LR scheduling baked in.

The returned functions are pure (state, batch, rng) -> (state, metrics) and carry
*all* mutable training state in one pytree, so checkpointing and restart are exact.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import OptimizerConfig, TrainConfig
from ..models.lm import LM
from ..optim import (adamw_init, adamw_update, clip_by_global_norm, compress_grads,
                     init_compression_state, make_schedule)


def init_train_state(model: LM, key, opt_cfg: OptimizerConfig,
                     use_mems: bool = False, batch: int = 0) -> Dict[str, Any]:
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    if opt_cfg.grad_compression != "none":
        state["err"] = init_compression_state(params)
    if use_mems and model.cfg.xl_memory:
        from ..models.stack import init_mems
        state["mems"] = init_mems(model.cfg, batch, model.dtype)
    return state


def make_train_step(model: LM, opt_cfg: OptimizerConfig,
                    grad_accum: int = 1):
    sched = make_schedule(opt_cfg)
    use_mems = bool(model.cfg.xl_memory)

    def loss_for(params, batch, rng, mems):
        out = model.loss(params, batch, rng=rng, train=True, mems=mems)
        loss, aux = out
        if use_mems:
            metrics, new_mems = aux
        else:
            metrics, new_mems = aux, None
        return loss, (metrics, new_mems)

    def compute_grads(params, batch, rng, mems):
        if grad_accum <= 1:
            (loss, (metrics, new_mems)), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch, rng, mems)
            return loss, metrics, new_mems, grads

        # microbatching: scan over grad_accum slices, accumulate fp32 grads.
        def micro(carry, xs):
            acc, mems_c = carry
            mb, r = xs
            (loss, (metrics, new_mems)), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, mb, r, mems_c)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum, acc, grads)
            return (acc, new_mems if use_mems else mems_c), (loss, metrics)

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
            batch)
        rngs = jax.random.split(rng, grad_accum)
        (grads, new_mems), (losses, metricss) = jax.lax.scan(
            micro, (zeros, mems), (mbs, rngs))
        loss = jnp.mean(losses)
        metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m, 0), metricss)
        return loss, metrics, (new_mems if use_mems else None), grads

    def train_step(state: Dict[str, Any], batch: Dict, rng) -> Tuple[Dict, Dict]:
        params = state["params"]
        mems = state.get("mems")
        rng = jax.random.fold_in(rng, state["opt"].step)
        loss, metrics, new_mems, grads = compute_grads(params, batch, rng, mems)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_state = dict(state)
        if "err" in state:
            grads, new_err = compress_grads(grads, state["err"],
                                            opt_cfg.grad_compression)
            new_state["err"] = new_err
        lr = sched(state["opt"].step)
        new_params, new_opt = adamw_update(grads, state["opt"], params, opt_cfg, lr)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        if new_mems is not None:
            new_state["mems"] = new_mems
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def make_eval_step(model: LM):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, rng=None, train=False)
        return loss, metrics
    return eval_step


def make_prefill_step(model: LM, max_len: int):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: LM):
    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return decode_step
