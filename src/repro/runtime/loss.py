"""Cross-entropy with bounded logits memory.

For vocab sizes like gemma3's 262k, materializing (tokens, vocab) logits dominates
activation memory (batch 256 x 4096 seq x 262k vocab = 0.5 PB unsharded). Two levers:

  1. vocab-sharded logits (logical 'vocab' -> model axis) so the softmax reduction is
     a psum over the TP axis — handled by the sharding constraint below;
  2. chunking over tokens with remat: forward keeps only one chunk's logits alive;
     backward recomputes them per chunk.

Both are beyond-paper memory optimizations recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..common import round_up
from ..sharding.logical import with_logical_constraint


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def _ce_dense(h: jax.Array, w: jax.Array, labels: jax.Array, mask: jax.Array,
              softcap: float, n_valid_vocab: int = 0) -> jax.Array:
    """Sum of token CE over valid positions. h (N,D), w (D,V), labels (N,)."""
    logits = _softcap(jnp.einsum("nd,dv->nv", h, w).astype(jnp.float32), softcap)
    logits = with_logical_constraint(logits, (None, "vocab"))
    if n_valid_vocab:      # padded vocab: exclude pad columns from the partition fn
        logits = jnp.where(jnp.arange(logits.shape[-1]) < n_valid_vocab,
                           logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.sum((lse - gold) * mask)


def chunked_cross_entropy(h: jax.Array, w: jax.Array, labels: jax.Array,
                          *, chunks: int = 0, softcap: float = 0.0,
                          mask: Optional[jax.Array] = None, n_valid_vocab: int = 0
                          ) -> Tuple[jax.Array, jax.Array]:
    """Mean next-token CE. h (B,S,D), w (D,V), labels (B,S). Returns (mean, n_tok)."""
    b, s, d = h.shape
    hf = h.reshape(-1, d)
    lf = labels.reshape(-1)
    mf = (mask.reshape(-1).astype(jnp.float32) if mask is not None
          else jnp.ones((b * s,), jnp.float32))
    n = hf.shape[0]

    if chunks <= 1:
        total = _ce_dense(hf, w, lf, mf, softcap, n_valid_vocab)
    else:
        npad = round_up(n, chunks)
        if npad != n:
            hf = jnp.pad(hf, ((0, npad - n), (0, 0)))
            lf = jnp.pad(lf, (0, npad - n))
            mf = jnp.pad(mf, (0, npad - n))
        hc = hf.reshape(chunks, npad // chunks, d)
        lc = lf.reshape(chunks, -1)
        mc = mf.reshape(chunks, -1)

        # remat: logits of each chunk are recomputed in backward, never all alive.
        ce_fn = jax.checkpoint(
            lambda hx, lx, mx: _ce_dense(hx, w, lx, mx, softcap, n_valid_vocab),
            policy=jax.checkpoint_policies.nothing_saveable)

        def body(acc, xs):
            hx, lx, mx = xs
            return acc + ce_fn(hx, lx, mx), None

        total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc, mc))

    n_tok = jnp.sum(mf)
    return total / jnp.maximum(n_tok, 1.0), n_tok
