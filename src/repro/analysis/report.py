"""Finding/Report containers shared by every analysis pass.

A pass returns ``(findings, checks)``: the list of contract violations it
could prove, and the number of individual facts it verified (so a pass that
silently checks nothing cannot masquerade as clean — the CLI and the pinned
snapshot test both assert the check counts stay above a floor).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class Finding:
    """One proven contract violation."""
    pass_name: str    # "pipeline" | "plans" | "vmem" | "sharding"
    check: str        # short machine id, e.g. "slot-overwrite"
    location: str     # where: "gather depth=3 m_tiles=1", a leaf path, ...
    detail: str       # human sentence: what failed and why it matters

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"[{self.pass_name}/{self.check}] {self.location}: {self.detail}"


@dataclasses.dataclass
class Report:
    """Aggregate over the passes one CLI/library invocation ran."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    checks: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, pass_name: str, findings: List[Finding], checks: int) -> None:
        self.findings.extend(findings)
        self.checks[pass_name] = self.checks.get(pass_name, 0) + checks

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> Dict:
        return {"ok": self.ok,
                "checks": dict(self.checks),
                "n_findings": len(self.findings),
                "findings": [f.to_dict() for f in self.findings]}

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def summary(self) -> str:
        lines = []
        for name in sorted(self.checks):
            n_bad = sum(1 for f in self.findings if f.pass_name == name)
            status = "OK" if n_bad == 0 else f"{n_bad} finding(s)"
            lines.append(f"  {name:<10} {self.checks[name]:>7} checks  {status}")
        for f in self.findings:
            lines.append(f"  {f}")
        verdict = "CLEAN" if self.ok else f"{len(self.findings)} FINDING(S)"
        lines.append(f"analysis: {verdict} "
                     f"({sum(self.checks.values())} facts verified)")
        return "\n".join(lines)
