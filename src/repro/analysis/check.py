"""CLI entry point: ``python -m repro.analysis.check [--all | per-pass flags]``.

Runs the kernel-contract passes and exits non-zero on any finding — CI runs
``--all`` as a hard gate before the benchmark job. ``--json PATH`` writes the
machine-readable report (uploaded as a CI artifact) in the ``Report.to_dict``
schema: {ok, checks: {pass: n}, n_findings, findings: [...]}.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from .report import Report

PASSES = ("pipeline", "plans", "vmem", "sharding")


def run_passes(names: Sequence[str]) -> Report:
    """Library entry: run the named passes, return the aggregate Report."""
    report = Report()
    for name in names:
        if name == "pipeline":
            from .pipeline import check_pipeline as fn
        elif name == "plans":
            from .plans import check_plans as fn
        elif name == "vmem":
            from .vmem import check_vmem as fn
        elif name == "sharding":
            from .sharding import check_sharding as fn
        else:
            raise ValueError(f"unknown analysis pass {name!r} "
                             f"(have {', '.join(PASSES)})")
        findings, checks = fn()
        report.add(name, findings, checks)
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Static kernel-contract verification (DMA pipelines, "
                    "plan invariants, VMEM budgets, sharding rules).")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass flag given)")
    for name in PASSES:
        ap.add_argument(f"--{name}", action="store_true",
                        help=f"run the {name} pass")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON report to PATH")
    args = ap.parse_args(argv)

    selected = [name for name in PASSES if getattr(args, name)]
    if args.all or not selected:
        selected = list(PASSES)

    report = run_passes(selected)
    print(report.summary())
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(report.to_json())
        print(f"json report: {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
