"""Pass 2: plan-invariant verifier for CvmmPlan / GatherPlan / DedupGatherPlan.

Every streamed kernel trusts its plan blindly: ``row_src`` routes HBM rows,
the ``run_start``/``run_off`` chunk table decides what each DMA descriptor
copies, ``sel_pos`` redirects per-token weighting. A wrong plan does not
crash — it silently gathers the wrong rows. This pass is the single oracle
for plan soundness; ``ops.plan_dma_stats(..., verify=True)`` and the property
suites call the same functions, so telemetry, tests and CI prove the same
contract.

``replay_chunk_table`` re-executes the chunk table in numpy EXACTLY the way
``cvmm._run_dmas`` walks it (one loop per static size class over the
``run_off`` boundaries), proving:

  class grouping     every entry inside class ci's boundary range describes a
                     chunk of exactly ``_RUN_SIZES[ci]`` rows; entries past
                     the last boundary are unused (``run_len == 0``)
  boundary legality  per-tile ``run_off`` starts at 0, is non-decreasing, and
                     never exceeds the tile's entry count
  chunk legality     chunks stay inside their tile and inside the source
                     array, and the source rows they claim are genuinely
                     contiguous in ``row_src`` (a DMA copies ``src..src+len``;
                     if ``row_src`` disagrees the copy lands wrong rows)
  exact coverage     every REAL slot (``row_src < n_rows``) is written by
                     exactly one chunk; sentinel slack slots by none

``verify_plan`` adds the per-plan-type structural invariants (permutations,
tile-expert consistency, sorted-unique prefix, ``sel_pos`` indirection).
``check_plans`` sweeps the three builders plus ``dispatch.ep_local_plan``
over adversarial routings (skewed, colliding, empty-expert, sub-tile).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from ..kernels import cvmm, ops
from .report import Finding

TM = ops.TM


def _bad(check: str, location: str, detail: str) -> Finding:
    return Finding("plans", check, location, detail)


def replay_chunk_table(plan, n_rows: int, x: Optional[np.ndarray] = None,
                       location: str = "plan"):
    """Numpy re-execution of the plan's DMA chunk table.

    Returns ``(out, n_dma, findings)``: the gathered tile-aligned array (zeros
    where no chunk writes; ``None`` when ``x`` is not given), the number of
    DMA descriptors a kernel pass would issue, and the invariant findings."""
    findings: List[Finding] = []
    rs = np.asarray(plan.row_src)
    rst = np.asarray(plan.run_start)
    rl = np.asarray(plan.run_len)
    nc = len(cvmm._RUN_SIZES)
    m_pad = rs.shape[0]
    n_tiles = m_pad // TM
    ro = np.asarray(plan.run_off)
    if ro.shape != (n_tiles * (nc + 1),):
        findings.append(_bad("run-off-shape", location,
                             f"run_off has shape {ro.shape}, expected "
                             f"({n_tiles * (nc + 1)},)"))
        return None, 0, findings
    ro = ro.reshape(n_tiles, nc + 1)
    out = None if x is None else np.zeros((m_pad, x.shape[1]), x.dtype)
    covered = np.zeros((m_pad,), np.int32)
    n_dma = 0
    for t in range(n_tiles):
        base = t * TM
        if ro[t, 0] != 0:
            findings.append(_bad("run-off-start", f"{location} tile {t}",
                                 f"first class boundary is {ro[t, 0]}, not 0"))
        if np.any(np.diff(ro[t]) < 0) or ro[t, nc] > TM:
            findings.append(_bad(
                "run-off-bounds", f"{location} tile {t}",
                f"class boundaries {ro[t].tolist()} are not a non-decreasing "
                f"sequence within [0, {TM}]"))
            continue
        for ci, sz in enumerate(cvmm._RUN_SIZES):
            for j in range(ro[t, ci], ro[t, ci + 1]):
                n_dma += 1
                if rl[base + j] != sz:
                    findings.append(_bad(
                        "class-mismatch", f"{location} tile {t} entry {j}",
                        f"entry sits in size-class {sz} but run_len says "
                        f"{int(rl[base + j])} — the kernel would copy {sz}"))
                off = int(rst[base + j])
                if not (0 <= off and off + sz <= TM):
                    findings.append(_bad(
                        "chunk-tile-overrun", f"{location} tile {t} entry {j}",
                        f"chunk [{off}, {off + sz}) leaves the {TM}-slot tile"))
                    continue
                src = int(rs[base + off])
                if not (0 <= src and src + sz <= n_rows):
                    findings.append(_bad(
                        "chunk-src-overrun", f"{location} tile {t} entry {j}",
                        f"chunk reads source rows [{src}, {src + sz}) from an "
                        f"array of {n_rows} rows"))
                    continue
                run = rs[base + off: base + off + sz]
                if not np.array_equal(run, np.arange(src, src + sz)):
                    findings.append(_bad(
                        "chunk-noncontiguous", f"{location} tile {t} entry {j}",
                        f"chunk claims contiguous sources [{src}, {src + sz}) "
                        f"but row_src there is {run.tolist()} — the DMA would "
                        f"land the wrong rows"))
                covered[base + off: base + off + sz] += 1
                if out is not None:
                    out[base + off: base + off + sz] = x[src: src + sz]
        tail = rl[base + ro[t, nc]: base + TM]
        if np.any(tail != 0):
            findings.append(_bad(
                "tail-not-empty", f"{location} tile {t}",
                f"entries past the last class boundary must be unused "
                f"(run_len 0), found {tail[tail != 0].tolist()}"))
    valid = rs < n_rows
    over = np.nonzero(valid & (covered != 1))[0]
    if over.size:
        findings.append(_bad(
            "coverage", location,
            f"{over.size} real slot(s) not fetched exactly once, e.g. slot "
            f"{int(over[0])} fetched {int(covered[over[0]])} times"))
    slack_hit = np.nonzero(~valid & (covered > 0))[0]
    if slack_hit.size:
        findings.append(_bad(
            "sentinel-fetched", location,
            f"{slack_hit.size} sentinel slack slot(s) covered by a chunk, "
            f"e.g. slot {int(slack_hit[0])} — slack must keep the zero fill"))
    bad_sentinel = np.nonzero(~valid & (rs != n_rows))[0]
    if bad_sentinel.size:
        findings.append(_bad(
            "sentinel-value", location,
            f"slack slots must hold the sentinel {n_rows}, found "
            f"{int(rs[bad_sentinel[0]])} at slot {int(bad_sentinel[0])}"))
    return out, n_dma, findings


def _verify_cvmm_plan(plan: ops.CvmmPlan, n_rows: int,
                      location: str) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    perm = np.asarray(plan.perm)
    gs = np.asarray(plan.group_sizes)
    new_pos = np.asarray(plan.new_pos)
    te = np.asarray(plan.tile_expert)
    rs = np.asarray(plan.row_src)
    gates = np.asarray(plan.gate_tiles).reshape(-1)
    m = perm.shape[0]
    e = gs.shape[0]
    if not np.array_equal(np.sort(perm), np.arange(m)):
        findings.append(_bad("perm", location,
                             "perm is not a permutation of the sorted rows"))
    if int(gs.sum()) != m or np.any(gs < 0):
        findings.append(_bad("group-sizes", location,
                             f"group_sizes sums to {int(gs.sum())}, expected "
                             f"{m} non-negative rows"))
    if np.any(np.diff(te) < 0) or np.any(te < 0) or np.any(te >= e):
        findings.append(_bad("tile-expert", location,
                             "tile_expert must be non-decreasing within "
                             f"[0, {e}), got {te.tolist()}"))
    if np.unique(new_pos).shape[0] != m or np.any(new_pos < 0) \
            or np.any(new_pos >= rs.shape[0]):
        findings.append(_bad("new-pos", location,
                             "new_pos is not an injection of the sorted rows "
                             "into the padded slots"))
    else:
        # Each sorted row's slot must land in a tile owned by its expert —
        # otherwise the kernel would multiply it with the wrong weight block.
        row_e = np.repeat(np.arange(e), gs)
        slot_e = te[new_pos // TM]
        wrong = np.nonzero(slot_e != row_e)[0]
        if wrong.size:
            findings.append(_bad(
                "tile-purity", location,
                f"{wrong.size} sorted row(s) placed in a tile of another "
                f"expert, e.g. row {int(wrong[0])} (expert "
                f"{int(row_e[wrong[0]])}) in a tile of expert "
                f"{int(slot_e[wrong[0]])}"))
    slack_gates = gates[rs >= n_rows]
    if slack_gates.size and np.any(slack_gates != 0.0):
        findings.append(_bad("gate-slack", location,
                             "gate_tiles must be exactly 0 on slack slots "
                             "(that zero is what kills slack outputs)"))
    if int((rs < n_rows).sum()) != m:
        findings.append(_bad("row-src-count", location,
                             f"{int((rs < n_rows).sum())} real slots for {m} "
                             f"sorted rows"))
    return findings, 6


def _verify_gather_plan(plan: ops.GatherPlan, n_rows: int,
                        location: str) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    rs = np.asarray(plan.row_src)
    tok = np.asarray(plan.tok_src)
    w = np.asarray(plan.weight_tiles).reshape(-1)
    valid = rs < n_rows
    m = int(valid.sum())
    if np.any(valid != (np.arange(rs.shape[0]) < m)):
        findings.append(_bad("slack-layout", location,
                             "GatherPlan keeps flat selection order: real "
                             "slots must form the prefix, slack the tail"))
    if np.any(tok[~valid] != tok.max(initial=0)) and np.any(valid):
        # slack tok_src is the n_tokens sentinel — must not scatter anywhere
        if np.any(tok[~valid] <= tok[valid].max(initial=-1)):
            findings.append(_bad("tok-slack", location,
                                 "slack slots carry a real destination token"))
    if np.any(w[~valid] != 0.0):
        findings.append(_bad("weight-slack", location,
                             "weight_tiles must be 0 on slack slots"))
    return findings, 3


def _verify_dedup_plan(plan: ops.DedupGatherPlan, n_rows: int,
                       location: str) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    rs = np.asarray(plan.row_src)
    sel = np.asarray(plan.sel_pos)
    valid = rs < n_rows
    u = int(valid.sum())
    if np.any(valid != (np.arange(rs.shape[0]) < u)):
        findings.append(_bad("slack-layout", location,
                             "dedup row_src must keep the valid prefix "
                             "contiguous (sentinels sort last)"))
    prefix = rs[:u]
    if u and (np.any(np.diff(prefix) <= 0)):
        findings.append(_bad("sorted-unique", location,
                             "dedup row_src prefix must be strictly "
                             "ascending (sorted, duplicates collapsed)"))
    if np.any(sel < 0) or np.any(sel >= rs.shape[0]) \
            or (sel.size and np.any(~valid[sel])):
        findings.append(_bad("sel-pos-range", location,
                             "sel_pos must map every selection to a REAL "
                             "compacted slot (never sentinel slack)"))
    elif u and not np.array_equal(np.unique(sel), np.arange(u)):
        findings.append(_bad("sel-pos-surjective", location,
                             "every compacted row must be referenced by at "
                             "least one selection — an unreferenced row was "
                             "fetched for nothing, a missing one never "
                             "existed in the selection"))
    return findings, 4


def verify_plan(plan, n_rows: int, location: str = "") -> List[Finding]:
    """Every invariant of one plan provable without the original routing.

    The shared chunk-table replay plus the per-type structural checks; returns
    findings (empty = proven sound). The routing-aware cross-checks (plan
    fields vs the idx/gates that built them) live in ``check_plans``."""
    location = location or type(plan).__name__
    # arange "activations" make row identity visible to the replay compare
    x = np.arange(n_rows, dtype=np.int64).reshape(-1, 1)
    out, _, findings = replay_chunk_table(plan, n_rows, x, location)
    if out is not None:
        want = np.where((np.asarray(plan.row_src) < n_rows)[:, None],
                        np.asarray(plan.row_src)[:, None], 0)
        if not np.array_equal(out, want):
            findings.append(_bad(
                "gather-mismatch", location,
                "chunk-table replay does not reproduce take(row_src) with "
                "zero fill"))
    if isinstance(plan, ops.CvmmPlan):
        findings += _verify_cvmm_plan(plan, n_rows, location)[0]
    elif isinstance(plan, ops.GatherPlan):
        findings += _verify_gather_plan(plan, n_rows, location)[0]
    elif isinstance(plan, ops.DedupGatherPlan):
        findings += _verify_dedup_plan(plan, n_rows, location)[0]
    return findings


# ---------------------------------------------------------------------------
# The sweep: adversarial routings through every plan builder
# ---------------------------------------------------------------------------

# (name, n_tokens, n_experts_or_rows, k_or_s, style)
_MOE_CASES = (
    ("moe-random", 100, 6, 3, "random"),
    ("moe-skewed", 300, 3, 2, "skewed"),        # every row to expert 0
    ("moe-empty-experts", 57, 5, 2, "subset"),  # some experts get no rows
    ("moe-subtile", 8, 4, 2, "random"),         # n*k < TM
    ("moe-k1", 130, 2, 1, "random"),
)
_GATHER_CASES = (
    ("gather-random", 40, 300, 4),
    ("gather-colliding", 100, 64, 8),           # heavy shared-row selection
    ("gather-sparse", 5, 1000, 3),
    ("gather-subtile", 3, 50, 2),
)
_EP_CASES = ((2, 256), (4, 128), (1, 384), (3, 64))
# (name, n_tokens, k, n_experts, d_model, expert_size) — the serving decode
# shape classes: tiny-M batches whose cached skeletons the engine reuses
_DECODE_CASES = (
    ("decode-b4", 4, 2, 4, 64, 32),
    ("decode-b8", 8, 2, 4, 64, 32),
    ("decode-b1-k1", 1, 1, 2, 64, 32),
    ("decode-b2-e8", 2, 2, 8, 128, 64),
)


def check_plans() -> Tuple[List[Finding], int]:
    import jax.numpy as jnp

    findings: List[Finding] = []
    checks = 0
    rng = np.random.RandomState(0)

    for name, n, e, k, style in _MOE_CASES:
        if style == "skewed":
            idx = np.zeros((n, k), np.int32)
        elif style == "subset":
            idx = rng.randint(0, max(e - 2, 1), size=(n, k)).astype(np.int32)
        else:
            idx = rng.randint(0, e, size=(n, k)).astype(np.int32)
        gates = rng.rand(n, k).astype(np.float32)
        plan = ops.make_moe_plan(jnp.asarray(idx), jnp.asarray(gates), n, e)
        findings += verify_plan(plan, n, name)
        checks += 10
        # routing cross-check: slot contents == the sorted selection
        perm = np.asarray(plan.perm)
        new_pos = np.asarray(plan.new_pos)
        tok = np.repeat(np.arange(n, dtype=np.int32), k)
        if not np.array_equal(np.asarray(plan.row_src)[new_pos], tok[perm]):
            findings.append(_bad(
                "routing-mismatch", name,
                "row_src[new_pos] != token of the sorted selection"))
        gexp = np.zeros((plan.m_pad,), np.float32)
        gexp[new_pos] = gates.reshape(-1)[perm]
        if not np.allclose(np.asarray(plan.gate_tiles).reshape(-1), gexp):
            findings.append(_bad(
                "gate-mismatch", name,
                "gate_tiles disagree with the routed gate values"))
        checks += 2

    for name, n, rows, s in _GATHER_CASES:
        idx = rng.randint(0, rows, size=(n, s)).astype(np.int32)
        w = rng.rand(n, s).astype(np.float32)
        gplan = ops.make_gather_plan(jnp.asarray(idx), jnp.asarray(w), rows)
        findings += verify_plan(gplan, rows, name)
        m = n * s
        if not np.array_equal(np.asarray(gplan.row_src)[:m], idx.reshape(-1)):
            findings.append(_bad("routing-mismatch", name,
                                 "GatherPlan row_src prefix != flat idx"))
        checks += 9

        dname = name.replace("gather", "dedup")
        dplan = ops.make_dedup_gather_plan(jnp.asarray(idx), jnp.asarray(w),
                                           rows)
        findings += verify_plan(dplan, rows, dname)
        sel = np.asarray(dplan.sel_pos)
        if not np.array_equal(np.asarray(dplan.row_src)[sel], idx.reshape(-1)):
            findings.append(_bad(
                "indirection-mismatch", dname,
                "row_src[sel_pos] must reproduce the flat selection — the "
                "scatter-side weighting depends on it"))
        if not np.array_equal(np.asarray(dplan.tok_src),
                              np.repeat(np.arange(n, dtype=np.int32), s)):
            findings.append(_bad("tok-src", dname,
                                 "dedup tok_src != flat selection tokens"))
        checks += 10

    from ..core import dispatch
    for e_local, cap_g in _EP_CASES:
        plan = dispatch.ep_local_plan(e_local, cap_g)
        findings += verify_plan(plan, e_local * cap_g,
                                f"ep e_local={e_local} cap_g={cap_g}")
        checks += 10

    # Decode skeletons: the routing-free layout must assemble into a plan
    # that passes the SAME oracle as every per-call plan, for any routing —
    # otherwise the engine's cached-skeleton shortcut could drift silently.
    for name, n, k, e, d_model, esz in _DECODE_CASES:
        skel = ops.make_decode_plan(n, k, e, d_model, esz)
        if skel is None:
            findings.append(_bad(
                "decode-no-tile", name,
                f"no fitting tile for n={n} k={k} e={e} d={d_model} "
                f"g={esz} — the decode shape classes must stay servable"))
            continue
        # the skeleton's dedup token gather is a plan in its own right
        findings += verify_plan(skel.gather, n, f"{name}/gather")
        te_want = np.repeat(np.arange(e, dtype=np.int32), skel.cap // TM)
        if not np.array_equal(np.asarray(skel.tile_expert), te_want):
            findings.append(_bad(
                "decode-tile-expert", name,
                "skeleton tile_expert != repeat(arange(e), cap//TM) — the "
                "static expert layout is what makes the cache routing-free"))
        checks += 11
        idx = rng.randint(0, e, size=(n, k)).astype(np.int32)
        gates = rng.rand(n, k).astype(np.float32)
        full = ops.assemble_decode_plan(skel, jnp.asarray(idx),
                                        jnp.asarray(gates))
        findings += verify_plan(full, n, name)
        perm = np.asarray(full.perm)
        new_pos = np.asarray(full.new_pos)
        tok = np.repeat(np.arange(n, dtype=np.int32), k)
        if not np.array_equal(np.asarray(full.row_src)[new_pos], tok[perm]):
            findings.append(_bad(
                "routing-mismatch", name,
                "assembled row_src[new_pos] != token of the sorted selection"))
        gexp = np.zeros((full.m_pad,), np.float32)
        gexp[new_pos] = gates.reshape(-1)[perm]
        if not np.allclose(np.asarray(full.gate_tiles).reshape(-1), gexp):
            findings.append(_bad(
                "gate-mismatch", name,
                "assembled gate_tiles disagree with the routed gate values"))
        if not np.array_equal(np.asarray(full.tile_expert),
                              np.asarray(skel.tile_expert)):
            findings.append(_bad(
                "decode-tile-drift", name,
                "assembled plan's tile_expert differs from the skeleton's — "
                "the cached GEMM layout would not match the materialized one"))
        slots = np.asarray(ops.decode_slots(skel, jnp.asarray(idx)))
        if not np.array_equal(np.sort(new_pos), np.sort(slots)):
            findings.append(_bad(
                "decode-slot-mismatch", name,
                "decode_slots() and the assembled new_pos place selections "
                "in different padded rows"))
        checks += 14
    return findings, checks
