"""Kernel-contract analyzer: static verification of the invariants the
Pallas kernels, plan builders, tile pickers, and sharding tables rely on —
proven offline, before any kernel launches or mesh is built.

Module map
----------
report.py     ``Finding`` / ``Report`` containers. Every pass returns
              ``(findings, checks)`` — violations plus the count of facts it
              verified, so an accidentally-empty sweep cannot look clean.
pipeline.py   Pass 1: DMA-pipeline hazard checker. Replays the kernels' OWN
              ``cvmm.stream_schedule_step`` control skeleton with recording
              callbacks over every (family, depth, grid, pass-count) and
              proves issue/wait pairing, no slot overwrite, waited-data
              compute, exact coverage, and clean warmup/drain.
plans.py      Pass 2: plan-invariant verifier. Numpy re-execution of the DMA
              chunk tables (exactly-once coverage, legal boundaries, never
              fetching sentinel slack) plus the per-plan structural
              invariants; ``ops.plan_dma_stats(verify=True)`` and the
              property tests call the same oracle.
vmem.py       Pass 3: VMEM-budget prover. Enumerates every tile candidate
              the autotuner can emit and proves fit against an independently
              itemized launch inventory; cross-checks the tuner's ws_*
              formulas and the (width, depth) pairs ops.py actually threads.
sharding.py   Pass 4: sharding-table analyzer. PARAM_AXES x rule sets x
              every registered mesh axis layout under strict duplicate
              detection, plus full registry-model leaf closure and the
              pod_err wrapping.
check.py      The CLI (``python -m repro.analysis.check --all``) and the
              ``run_passes`` library entry CI and tests share.

The passes verify the real artifacts — the shared schedule skeleton, real
``ops.make_*_plan`` outputs, the tuner's real candidate enumerator, real
``eval_shape`` model trees — so a seeded mutation in production code is
caught here, not just in whichever integration test happens to hit it.
"""
from .report import Finding, Report

__all__ = ["Finding", "PASSES", "Report", "run_passes"]


def __getattr__(name):
    # Lazy: ``python -m repro.analysis.check`` imports this package first,
    # and an eager ``from .check import ...`` would put check.py in
    # sys.modules before runpy executes it (RuntimeWarning).
    if name in ("PASSES", "run_passes"):
        from . import check
        return getattr(check, name)
    raise AttributeError(name)
