"""Pass 1: DMA-pipeline hazard checker for the streamed gather kernels.

The streamed kernels in ``kernels/cvmm.py`` (fused w1, bare gather, the dW
streams) all drive their HBM->VMEM row DMAs through ONE control skeleton,
``cvmm.stream_schedule_step`` — the kernels bind real
``_gather_issue``/``_gather_wait`` callbacks and a traced grid index, while
this pass replays the SAME function with recording callbacks over every
concrete grid in a sweep. Because the skeleton is shared (not transcribed),
a schedule bug — a dropped wait, an off-by-one warmup, an unguarded prefetch
— changes both the kernels and the replay, and the replay proves it here
before a kernel ever corrupts data at runtime.

What is proven, per (pipeline family x depth x grid length x pass count):

  issue/wait pairing   every ``wait(t)`` matches the in-flight DMA of the same
                       tile in the same slot (the per-slot semaphore is FIFO;
                       a mismatched wait would consume another tile's signal)
  no slot overwrite    an ``issue`` never targets a slot whose previous DMA
                       has not been waited (the zero-fill + fresh DMA would
                       race the in-flight copy)
  compute reads waited data   the compute step of tile ``i`` reads the slot
                       that holds tile ``i``'s waited data, not a slot a later
                       prefetch already clobbered
  coverage             every tile 0..m_tiles-1 is issued exactly once and
                       waited exactly once per pass; no out-of-range tile is
                       ever issued (its chunk table does not exist)
  warmup/drain         boundary grids (``m_tiles < n_buffers``) stay legal,
                       and no DMA is left in flight at the end of a pass — the
                       dW kernels re-enter the stream once per outer pass, so
                       a leaked DMA would collide with the next warmup

Depths swept: ``autotune.SUPPORTED_DEPTHS`` (2/3/4) — the union of what any
family's candidate enumerator can emit — for every entry in
``cvmm.STREAMED_PIPELINES``.
"""
from __future__ import annotations

from typing import List, Tuple

from ..kernels import autotune, cvmm
from .report import Finding

# Grid lengths swept: 1..MAX_TILES covers every warmup/drain regime — grids
# shorter than the deepest pipeline, equal to it, and long enough that the
# steady state (wait + prefetch) repeats.
MAX_TILES = 9
REENTRANT_PASSES = (1, 3)


def replay_stream(m_tiles: int, n_buffers: int,
                  n_passes: int = 1) -> List[Tuple[str, int, int]]:
    """Replay ``cvmm.stream_schedule_step`` over a concrete grid.

    Returns the flat event list [(kind, tile, slot), ...] with kind one of
    "issue" / "wait" / "compute" / "pass_end" (tile = pass index, slot = -1
    for pass_end markers), exactly in the order the kernel executes them."""
    events: List[Tuple[str, int, int]] = []

    def issue(t):
        events.append(("issue", int(t), int(cvmm.stream_slot(t, n_buffers))))

    def wait(t):
        events.append(("wait", int(t), int(cvmm.stream_slot(t, n_buffers))))

    def when(cond, fn):
        if cond:
            fn()

    for p in range(n_passes):
        for i in range(m_tiles):
            slot = cvmm.stream_schedule_step(i, m_tiles, n_buffers,
                                             issue=issue, wait=wait, when=when)
            events.append(("compute", i, int(slot)))
        events.append(("pass_end", p, -1))
    return events


def check_stream(m_tiles: int, n_buffers: int, n_passes: int = 1,
                 family: str = "stream") -> Tuple[List[Finding], int]:
    """Verify one replayed schedule against the hazard invariants."""
    loc = (f"{family} depth={n_buffers} m_tiles={m_tiles}"
           + (f" passes={n_passes}" if n_passes > 1 else ""))

    def bad(check: str, detail: str) -> Finding:
        return Finding("pipeline", check, loc, detail)

    findings: List[Finding] = []
    checks = 0
    in_flight = {}          # slot -> tile whose DMA has been issued, not waited
    resident = {}           # slot -> tile whose data has been waited (readable)
    issued = {}             # tile -> issue count, this pass
    waited = {}             # tile -> wait count, this pass

    for kind, t, slot in replay_stream(m_tiles, n_buffers, n_passes):
        if kind == "issue":
            checks += 3
            if not (0 <= t < m_tiles):
                findings.append(bad(
                    "issue-out-of-range",
                    f"issued tile {t}, but the grid has {m_tiles} tiles — "
                    f"its chunk table does not exist"))
            if slot in in_flight:
                findings.append(bad(
                    "slot-overwrite",
                    f"issue of tile {t} zero-fills slot {slot} while tile "
                    f"{in_flight[slot]}'s DMA into it is still in flight"))
            issued[t] = issued.get(t, 0) + 1
            if issued[t] > 1:
                findings.append(bad(
                    "double-issue",
                    f"tile {t} issued {issued[t]} times in one pass"))
            in_flight[slot] = t
            resident.pop(slot, None)          # zero-fill clobbers old data
        elif kind == "wait":
            checks += 1
            if in_flight.get(slot) != t:
                have = in_flight.get(slot)
                findings.append(bad(
                    "wait-mismatch",
                    f"wait for tile {t} on slot {slot}, but the slot holds "
                    + (f"tile {have}'s DMA" if have is not None
                       else "no in-flight DMA — the wait would hang or "
                            "consume a stale semaphore signal")))
            else:
                del in_flight[slot]
                resident[slot] = t
            waited[t] = waited.get(t, 0) + 1
        elif kind == "compute":
            checks += 1
            if resident.get(slot) != t:
                findings.append(bad(
                    "compute-unwaited",
                    f"compute of tile {t} reads slot {slot}, which holds "
                    f"{'tile %s' % resident[slot] if slot in resident else 'no waited data'}"))
        else:  # pass_end
            checks += 2
            if in_flight:
                findings.append(bad(
                    "leaked-dma",
                    f"pass ended with DMA(s) still in flight: "
                    f"{sorted(in_flight.items())} — the next warmup would "
                    f"overwrite them"))
            missing = [i for i in range(m_tiles)
                       if issued.get(i, 0) != 1 or waited.get(i, 0) != 1]
            if missing:
                findings.append(bad(
                    "coverage",
                    f"tiles not issued+waited exactly once this pass: "
                    f"{missing} (issued={issued}, waited={waited})"))
            issued, waited = {}, {}
    return findings, checks


def check_pipeline() -> Tuple[List[Finding], int]:
    """Sweep every streamed-pipeline family at every supported depth."""
    findings: List[Finding] = []
    checks = 0
    for family, info in sorted(cvmm.STREAMED_PIPELINES.items()):
        passes = REENTRANT_PASSES if info["reentrant"] else (1,)
        for depth in autotune.SUPPORTED_DEPTHS:
            for m_tiles in range(1, MAX_TILES + 1):
                for n_passes in passes:
                    f, c = check_stream(m_tiles, depth, n_passes, family)
                    findings += f
                    checks += c
    return findings, checks
