"""Pass 3: VMEM-budget prover for every tile the autotuner can emit.

The tile pickers in ``kernels/autotune.py`` are the only thing standing
between a kernel launch and a Mosaic "scoped memory exceeded" crash — or
worse, a tuned cache entry that fit under yesterday's budget and silently
busts today's. This pass closes the loop offline: it enumerates EVERY
candidate every family's enumerator can produce (``enumerate_candidates``,
both the disabled-tuner heuristic space and the full tuned space — the cache
layer only ever honors entries that are still members of that list, so this
sweep covers every tile ``decide()`` can return) and proves each one fits
``default_vmem_budget()`` for every hardware model in
``roofline.analysis.HARDWARE_MODELS``.

The fit proof uses an INDEPENDENT working-set model: ``launch_inventory``
itemizes the VMEM-resident buffers of each kernel launch straight from the
``scratch_shapes``/BlockSpec shapes in ``kernels/cvmm.py`` (each entry below
cites its launch). The tuner's closed-form ``ws_*`` formulas are then
cross-checked against the itemized sum ("formula-drift"): if someone grows a
kernel's scratch or adds an output block without updating the tuner's
accounting, the two models disagree and the pass fails — before the
undersized budget check ever lets a busting tile through.

Accounting conventions (shared with the tuner; the drift check enforces
them): manually-managed gather scratch is exact; Mosaic-pipelined blocked
operands/outputs of the streamed kernels count 2x (revolving buffers); the
plain blocked GEMM counts single-buffered, its pipelining headroom is what
``KERNEL_VMEM_FRACTION`` leaves free.

The threading check then resolves real ``ops.fused_mlp_tiles`` /
``ops.planned_call_tiles`` / ``ops.plan_sort_kernels`` decisions over a shape
grid and proves every (width, depth) pair a launch actually binds is itself a
member of that launch's candidate list — the invariant that caught the fused
w1 training launch borrowing the inference decision's pipeline depth (fixed
by giving ``FusedTiles`` a ``w1_train_nb``).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from ..kernels import autotune
from ..roofline.analysis import HARDWARE_MODELS
from .report import Finding

TM = autotune.TM
LANE = autotune.LANE


def _bad(check: str, location: str, detail: str) -> Finding:
    return Finding("vmem", check, location, detail)


# ---------------------------------------------------------------------------
# Independent launch inventory (shapes cited from kernels/cvmm.py)
# ---------------------------------------------------------------------------

def launch_inventory(family: str, dims: Dict[str, int],
                     tiles: Dict[str, int]) -> List[Tuple[str, int]]:
    """Itemized VMEM-resident buffers of one kernel launch: [(what, bytes)].

    Derived from the pallas_call scratch_shapes and BlockSpec block shapes,
    NOT from the tuner's formulas — the drift check compares the two."""
    b = dims["b"]
    if family in ("pick_tn", "decode_gemm"):
        # cvmm_pallas / cvmm_fused_w2_pallas: x block (TM, K), weight block
        # (1, K, tn), f32 accumulator-sized output block (TM, tn). The
        # decode_gemm shape-class launches the SAME kernel (ops.DecodePlan's
        # grouped GEMMs), so the per-step inventory is identical — only the
        # cost model and reference pass differ.
        k, tn = dims["k_pad"], tiles["tn"]
        return [("x block (TM,K)", TM * k * b),
                ("w block (1,K,tn)", k * tn * b),
                ("out block (TM,tn) f32", TM * tn * 4)]
    if family == "fused_w1":
        # cvmm_fused_w1_pallas: scratch pltpu.VMEM((n_buffers, TM, K)),
        # n_weights weight blocks (1, K, tn), n_out output blocks (TM, tn)
        # kept in f32-width accumulators; blocked refs pipelined 2x.
        k, tn = dims["k_pad"], tiles["tn"]
        nb = tiles["n_buffers"]
        nw, no = dims["n_weights"], dims["n_out"]
        return [("gather scratch (nb,TM,K)", nb * TM * k * b),
                ("w blocks (1,K,tn) x2", 2 * nw * k * tn * b),
                ("out blocks (TM,tn) x2", 2 * no * TM * tn * max(b, 4))]
    if family == "streamed_dw":
        # cvmm_dw_streamed_pallas: scratch pltpu.VMEM((n_buffers, TM, W_s)),
        # blocked operand (TM, tb), f32 output block (1, K, tb)/(1, tb, N) —
        # W_stream * tb either way; blocked refs pipelined 2x.
        sw, tb = dims["stream_w"], tiles["tb"]
        nb = tiles["n_buffers"]
        return [("gather scratch (nb,TM,Ws)", nb * TM * sw * b),
                ("operand block (TM,tb) x2", 2 * TM * tb * b),
                ("dW block (Ws,tb) f32 x2", 2 * sw * tb * 4)]
    if family in ("gather", "gather_dedup"):
        # cvmm_gather_rows_pallas: scratch pltpu.VMEM((n_buffers, TM, K)),
        # output block (TM, K) pipelined 2x.
        k = dims["k_pad"]
        nb = tiles["n_buffers"]
        return [("gather scratch (nb,TM,K)", nb * TM * k * b),
                ("out block (TM,K) x2", 2 * TM * k * b)]
    raise ValueError(f"unknown kernel family {family!r}")


def launch_bytes(family: str, dims: Dict[str, int],
                 tiles: Dict[str, int]) -> int:
    return sum(n for _, n in launch_inventory(family, dims, tiles))


def tuner_bytes(family: str, dims: Dict[str, int],
                tiles: Dict[str, int]) -> int:
    """The tuner's own closed-form working set for the same launch."""
    b = dims["b"]
    if family in ("pick_tn", "decode_gemm"):
        return autotune.ws_matmul_tile(dims["k_pad"], tiles["tn"], b)
    if family == "fused_w1":
        return autotune.ws_fused_w1(dims["k_pad"], tiles["tn"], b,
                                    dims["n_weights"], dims["n_out"],
                                    tiles["n_buffers"])
    if family == "streamed_dw":
        return autotune.ws_streamed_dw(dims["stream_w"], tiles["tb"], b,
                                       tiles["n_buffers"])
    return autotune.ws_gather(dims["k_pad"], b, tiles["n_buffers"])


# ---------------------------------------------------------------------------
# Shape grids: the padded dims production code can key the tuner with
# ---------------------------------------------------------------------------

_WIDTHS = (128, 256, 512, 640, 1024, 2048, 4096)


def _dims_grid(family: str):
    if family == "pick_tn":
        return [{"k_pad": k, "n_pad": n, "b": b}
                for k in (128, 512, 1024, 4096) for n in _WIDTHS
                for b in (2, 4)]
    if family == "decode_gemm":
        # Decode GEMMs key on (d_pad, g_pad) pairs of real expert MLPs — a
        # smaller grid than pick_tn's training sweep, but both orientations
        # (w1: d->g, w2: g->d) of each shape are covered.
        return [{"k_pad": k, "n_pad": n, "b": b}
                for k in (128, 512, 1024) for n in (128, 512, 640, 1024)
                for b in (2, 4)]
    if family == "fused_w1":
        return [{"k_pad": k, "n_pad": n, "b": b, "n_weights": nw,
                 "n_out": no}
                for k in (128, 512, 1024) for n in _WIDTHS for b in (2, 4)
                for nw in (1, 2) for no in (1, 2, 3)]
    if family == "streamed_dw":
        return [{"stream_w": sw, "block_w": bw, "b": b}
                for sw in (128, 512, 1024, 4096) for bw in _WIDTHS
                for b in (2, 4)]
    return [{"k_pad": k, "b": b} for k in _WIDTHS + (8192,)
            for b in (1, 2, 4)]


def _width_key(family: str) -> str:
    return "tb" if family == "streamed_dw" else "tn"


def _min_tiles(family: str, dims: Dict[str, int]) -> Dict[str, int]:
    """The smallest candidate the enumerator could ever offer."""
    t = {"tm": TM, _width_key(family): LANE, "n_buffers": 2}
    if family in ("pick_tn", "decode_gemm"):
        del t["n_buffers"]
    if family in ("gather", "gather_dedup"):
        del t[_width_key(family)]
    return t


def _check_candidate_space(budget: int, where: str):
    findings: List[Finding] = []
    checks = 0
    for family in autotune.families():
        wk = _width_key(family)
        depths = autotune.FAMILY_DEPTHS[family]
        for dims in _dims_grid(family):
            dimtag = ",".join(f"{k}={v}" for k, v in sorted(dims.items()))
            for tuned in (False, True):
                loc = (f"{family}[{dimtag}] {where}"
                       + (" tuned" if tuned else ""))
                cands = autotune.enumerate_candidates(family, dims,
                                                      budget=budget,
                                                      tuned=tuned)
                for c in cands:
                    checks += 5
                    ws = launch_bytes(family, dims, c)
                    if ws > budget:
                        findings.append(_bad(
                            "budget", loc,
                            f"candidate {c} needs {ws} bytes of VMEM, budget "
                            f"is {budget} — this tile would crash or spill "
                            f"at launch"))
                    tws = tuner_bytes(family, dims, c)
                    if tws != ws:
                        findings.append(_bad(
                            "formula-drift", loc,
                            f"tuner accounts {tws} bytes for {c}, the launch "
                            f"inventory sums to {ws} — the ws_* formula and "
                            f"the kernel's scratch/blocks disagree"))
                    if c.get("tm", TM) != TM:
                        findings.append(_bad(
                            "tm", loc, f"candidate {c} uses tm != {TM}; the "
                            f"plan layout bakes {TM} in"))
                    if wk in c and (c[wk] % LANE
                                    or dims.get("n_pad",
                                                dims.get("block_w",
                                                         c[wk])) % c[wk]):
                        findings.append(_bad(
                            "width", loc,
                            f"candidate width {c[wk]} is not a LANE multiple "
                            f"dividing the padded dim"))
                    nb = c.get("n_buffers")
                    legal = depths if tuned else ((2,) if depths else ())
                    if (nb is None) != (not depths) or \
                            (nb is not None and nb not in legal):
                        findings.append(_bad(
                            "depth", loc,
                            f"candidate depth {nb} is outside FAMILY_DEPTHS"
                            f"[{family!r}] for this tuner mode ({legal})"))
                checks += 1
                if not cands and launch_bytes(
                        family, dims, _min_tiles(family, dims)) <= budget:
                    findings.append(_bad(
                        "needless-degradation", loc,
                        f"no candidates offered although the minimal tile "
                        f"fits the {budget}-byte budget — callers would "
                        f"degrade to the slow path for nothing"))
                if cands and "n_buffers" in cands[0]:
                    checks += 1
                    d0 = min(c["n_buffers"] for c in cands)
                    w0 = max(c[wk] for c in cands
                             if c["n_buffers"] == d0) if wk in cands[0] else \
                        None
                    if cands[0]["n_buffers"] != d0 or \
                            (w0 is not None and cands[0][wk] != w0):
                        findings.append(_bad(
                            "heuristic-order", loc,
                            f"first candidate {cands[0]} is not the "
                            f"shallowest-depth/widest heuristic answer"))
    return findings, checks


# ---------------------------------------------------------------------------
# Tile threading: the pairs ops.py actually binds per launch
# ---------------------------------------------------------------------------

_THREAD_SHAPES = ((128, 512), (512, 2048), (1024, 4096), (256, 640))


def _check_threading():
    import jax.numpy as jnp
    from ..kernels import cvmm as cvmm_mod
    from ..kernels import ops

    findings: List[Finding] = []
    checks = 0
    budget = cvmm_mod.VMEM_BUDGET
    for d_model, g in _THREAD_SHAPES:
        for dtype in (jnp.float32, jnp.bfloat16):
            for glu in (False, True):
                b = jnp.dtype(dtype).itemsize
                nw = 2 if glu else 1
                d_pad = -(-d_model // LANE) * LANE
                g_pad = -(-g // LANE) * LANE
                loc = (f"fused d={d_model} g={g} b={b}"
                       + (" glu" if glu else ""))
                t = ops.fused_mlp_tiles(d_model, g, dtype, glu)
                if t is None:
                    continue
                # Every launch in ops._fused_fwd_impl/_fused_bwd, as the
                # (family, dims, width, depth) it binds. Each pair must be a
                # member of its own launch's tuned candidate list — i.e. a
                # combination some single tuner decision proved fits.
                launches = [
                    ("fused_w1", {"k_pad": d_pad, "n_pad": g_pad, "b": b,
                                  "n_weights": nw, "n_out": 1},
                     {"tm": TM, "tn": t.w1_tn, "n_buffers": t.w1_nb}),
                    ("fused_w1", {"k_pad": d_pad, "n_pad": g_pad, "b": b,
                                  "n_weights": nw, "n_out": 1 + nw},
                     {"tm": TM, "tn": t.w1_train_tn,
                      "n_buffers": t.w1_train_nb}),
                    ("fused_w1", {"k_pad": d_pad, "n_pad": g_pad, "b": b,
                                  "n_weights": 1, "n_out": 1},
                     {"tm": TM, "tn": t.t0_tn, "n_buffers": t.t0_nb}),
                    ("pick_tn", {"k_pad": g_pad, "n_pad": d_pad, "b": b},
                     {"tm": TM, "tn": t.w2_tn}),
                    ("streamed_dw", {"stream_w": d_pad, "block_w": g_pad,
                                     "b": b},
                     {"tm": TM, "tb": t.dw_tb, "n_buffers": t.dw_nb}),
                ]
                for family, dims, tiles in launches:
                    checks += 2
                    cands = autotune.enumerate_candidates(family, dims,
                                                          budget=budget,
                                                          tuned=True)
                    if tiles not in cands:
                        findings.append(_bad(
                            "threading", loc,
                            f"{family} launch binds {tiles}, which is not in "
                            f"its own candidate list — a (width, depth) "
                            f"combination no tuner decision proved fits"))
                    ws = launch_bytes(family, dims, tiles)
                    if ws > budget:
                        findings.append(_bad(
                            "threading-budget", loc,
                            f"{family} launch {tiles} needs {ws} bytes, "
                            f"budget {budget}"))
                p = ops.planned_call_tiles(d_model, g, dtype)
                if p is not None:
                    for kp, npad, tn in ((d_pad, g_pad, p.fwd_tn),
                                         (g_pad, d_pad, p.dx_tn),
                                         (TM, d_pad, p.dw_tk),
                                         (TM, g_pad, p.dw_tn)):
                        checks += 1
                        dims = {"k_pad": kp, "n_pad": npad, "b": b}
                        tiles = {"tm": TM, "tn": tn}
                        if tiles not in autotune.enumerate_candidates(
                                "pick_tn", dims, budget=budget):
                            findings.append(_bad(
                                "threading", loc,
                                f"planned GEMM tile {tiles} at {dims} is "
                                f"not a legal candidate"))
    return findings, checks


def check_vmem() -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    checks = 0
    for backend in sorted(HARDWARE_MODELS):
        hw = HARDWARE_MODELS[backend]
        budget = autotune.default_vmem_budget(hw)
        f, c = _check_candidate_space(budget, hw.name)
        findings += f
        checks += c
    f, c = _check_threading()
    return findings + f, checks + c
