"""Pass 4: sharding-table analyzer — PARAM_AXES x rule sets x mesh layouts.

The seed shipped a layout bug that only surfaced at mesh setup on a real
``--ffn pkm`` run: the PKM key tables ruled two positional dims onto the
'model' mesh axis and every sharded run crashed in NamedSharding
construction. ``strict_duplicate_check`` turned that class of bug into a
test failure — but only for the (model, mesh, rules) combinations a test
happens to build. This pass is the full offline closure of that check:

  table structure   every ``PARAM_AXES`` entry's axes tuple has exactly its
                    declared rank
  rule coverage     every logical axis the table uses has an explicit entry
                    in every rule set that can meet it (an absent key
                    silently replicates — each intentional replication must
                    be spelled out as ``None`` in the table, not implied)
  duplicate sweep   every table entry — at its own rank AND the scan-stacked
                    rank(+1) / superblock rank(+2) fallbacks — resolves under
                    strict mode for every rule set x every mesh axis layout
                    in ``launch.mesh.MESH_AXIS_LAYOUTS``
  model closure     every parameter leaf of every registry model variant
                    (sigma_moe / pkm / topk FFNs, real scan-stacked
                    ``eval_shape`` trees) reaches a PARAM_AXES rule — a leaf
                    falling through to the ``(None,) * rank`` fallback ships
                    fully replicated with nobody having decided that — and
                    its strict spec resolves under every rule set x layout
  pod_err closure   pod-stacked error-feedback wrapping (``{"err": ...}``
                    subtrees with a leading per-pod dim) shards its leading
                    dim over 'pod' for every leaf whose base layout is ruled

Meshes are built with every axis at size 1, so the sweep runs on any single
device; duplicate detection only depends on axis NAMES, never sizes.
"""
from __future__ import annotations

from typing import List, Tuple

from .report import Finding


def _bad(check: str, location: str, detail: str) -> Finding:
    return Finding("sharding", check, location, detail)


# Logical axes that only serving-state leaves (KV caches) carry; TRAIN/SP
# rule sets never meet them, so they are exempt from train-side coverage.
_SERVE_ONLY_AXES = ("kv_seq",)

_MODEL_FFNS = ("sigma_moe", "pkm", "topk")


def _rule_sets():
    from ..sharding import logical as L
    return (
        ("train", L.TRAIN_RULES),
        ("serve", L.SERVE_RULES),
        ("sp", L.SP_RULES),
        # context-parallel decode variant: kv heads not divisible by TP
        ("serve_ctx", L.serve_rules_for(8, 3)),
    )


def _meshes():
    import jax
    from ..launch.mesh import MESH_AXIS_LAYOUTS
    return [(ax, jax.make_mesh((1,) * len(ax), ax))
            for ax in MESH_AXIS_LAYOUTS]


def _check_table() -> Tuple[List[Finding], int]:
    from ..sharding import logical as L

    findings: List[Finding] = []
    checks = 0
    rule_sets = _rule_sets()
    meshes = _meshes()

    used_axes = sorted({a for axes in L.PARAM_AXES.values()
                        for a in axes if a is not None}
                       | {"layers", "pod_err", "batch", "seq"})
    for rname, rules in rule_sets:
        for ax in used_axes:
            checks += 1
            if ax in _SERVE_ONLY_AXES and rname in ("train", "sp"):
                continue
            if ax not in rules:
                findings.append(_bad(
                    "rule-coverage", f"{rname}[{ax!r}]",
                    f"logical axis {ax!r} is used by PARAM_AXES but has no "
                    f"entry in the {rname} rules — it replicates silently; "
                    f"spell intentional replication as an explicit None"))

    for (name, rank), axes in sorted(L.PARAM_AXES.items()):
        checks += 1
        if len(axes) != rank:
            findings.append(_bad(
                "rank-mismatch", f"PARAM_AXES[({name!r}, {rank})]",
                f"axes tuple {axes} has {len(axes)} entries for declared "
                f"rank {rank}"))
            continue
        # the entry itself, plus the scan-stacked and superblock fallbacks
        # _leaf_axes can derive from it
        variants = ((rank, axes),
                    (rank + 1, ("layers",) + axes),
                    (rank + 2, ("layers", "layers") + axes))
        for vrank, vaxes in variants:
            for rname, rules in rule_sets:
                for mesh_axes, mesh in meshes:
                    checks += 1
                    try:
                        L.spec_for_axes(vaxes, rules, mesh, strict=True,
                                        path=name)
                    except L.DuplicateMeshAxisError as e:
                        findings.append(_bad(
                            "duplicate-axis",
                            f"{name}[rank {vrank}] {rname} "
                            f"mesh={'x'.join(mesh_axes)}",
                            str(e)))
    return findings, checks


def _model_trees():
    """(variant name, scan-stacked eval_shape param tree) per registry FFN."""
    import jax
    from ..configs.archs import reduced
    from ..models.registry import build_model

    out = []
    for kind in _MODEL_FFNS:
        model = build_model(reduced("wt103-47m-moe"), ffn=kind)
        tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        out.append((kind, tree))
    return out


def _check_models() -> Tuple[List[Finding], int]:
    import jax
    from ..sharding import logical as L

    findings: List[Finding] = []
    checks = 0
    rule_sets = _rule_sets()
    meshes = _meshes()
    pod_mesh = next((m for ax, m in meshes if "pod" in ax), None)

    for kind, tree in _model_trees():
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in leaves:
            keys = L._path_keys(path)
            name, rank = (keys[-1] if keys else ""), leaf.ndim
            loc = f"{kind}:{jax.tree_util.keystr(path)}"
            checks += 1
            if rank and not any((name, rank - d) in L.PARAM_AXES
                                for d in (0, 1, 2)):
                findings.append(_bad(
                    "unruled-leaf", loc,
                    f"leaf {name!r} (rank {rank}) reaches no PARAM_AXES "
                    f"entry — it would ship fully replicated through the "
                    f"(None,)*rank fallback without anyone deciding that"))
                continue
            for rname, rules in rule_sets:
                for mesh_axes, mesh in meshes:
                    checks += 1
                    try:
                        L.spec_for(path, leaf, rules, mesh, strict=True)
                    except L.DuplicateMeshAxisError as e:
                        findings.append(_bad(
                            "duplicate-axis",
                            f"{loc} {rname} mesh={'x'.join(mesh_axes)}",
                            str(e)))

        # pod-stacked error-feedback wrapping: {"err": tree} with a leading
        # per-pod dim must shard that dim over 'pod' wherever the base
        # layout is ruled (optim/compress stores one residual per pod).
        if pod_mesh is None:
            continue
        wrapped = {"err": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((2,) + s.shape, s.dtype), tree)}
        for path, leaf in jax.tree_util.tree_flatten_with_path(wrapped)[0]:
            keys = L._path_keys(path)
            name = keys[-1] if keys else ""
            inner = L._leaf_axes(name, leaf.ndim - 1)
            if not any(a is not None for a in inner):
                continue
            checks += 1
            try:
                spec = L.spec_for(path, leaf, L.TRAIN_RULES, pod_mesh,
                                  strict=True)
            except L.DuplicateMeshAxisError as e:
                findings.append(_bad(
                    "duplicate-axis", f"{kind}:err{jax.tree_util.keystr(path)}",
                    str(e)))
                continue
            lead = tuple(spec)[0] if len(tuple(spec)) else None
            if lead != "pod":
                findings.append(_bad(
                    "pod-err", f"{kind}:{jax.tree_util.keystr(path)}",
                    f"pod-stacked error-feedback leaf {name!r} shards its "
                    f"leading per-pod dim as {lead!r}, expected 'pod' — "
                    f"every pod would store every pod's residual"))
    return findings, checks


def check_sharding() -> Tuple[List[Finding], int]:
    f1, c1 = _check_table()
    f2, c2 = _check_models()
    return f1 + f2, c1 + c2
