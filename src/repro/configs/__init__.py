from .base import (AttentionConfig, BlockSpecEntry, FFNConfig, MeshConfig, ModelConfig,
                   OptimizerConfig, SHAPES, ShapeConfig, SSMConfig, TrainConfig,
                   moe_ffn)
from .archs import ASSIGNED_ARCHS, get_config, list_archs, reduced

__all__ = [
    "AttentionConfig", "BlockSpecEntry", "FFNConfig", "MeshConfig", "ModelConfig",
    "OptimizerConfig", "SHAPES", "ShapeConfig", "SSMConfig", "TrainConfig", "moe_ffn",
    "ASSIGNED_ARCHS", "get_config", "list_archs", "reduced",
]
