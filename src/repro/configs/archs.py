"""The 10 assigned architectures (+ reduced smoke variants) and the paper's own configs.

Every entry is from the public literature; full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation). ``reduced()`` gives a CPU-runnable config of
the same family for smoke tests.
"""
from __future__ import annotations

from typing import Callable, Dict

from .base import (AttentionConfig, BlockSpecEntry, FFNConfig, ModelConfig, SSMConfig,
                   moe_ffn)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Assigned architectures
# ---------------------------------------------------------------------------

@register("mamba2-370m")
def mamba2_370m() -> ModelConfig:
    """[ssm] SSD (state-space duality), attention-free. arXiv:2405.21060."""
    return ModelConfig(
        name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
        vocab_size=50280, norm="rmsnorm", pos_encoding="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        ffn=FFNConfig(kind="none"),
        pattern=(BlockSpecEntry(mixer="ssm", ffn="none"),),
        tie_embeddings=True, subquadratic=True,
    )


@register("granite-moe-3b-a800m")
def granite_moe() -> ModelConfig:
    """[moe] IBM granite 3.0 MoE: 40 experts, top-8, GLU experts. hf:ibm-granite."""
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
        vocab_size=49155,
        attention=AttentionConfig(n_heads=24, n_kv_heads=8, head_dim=64),
        ffn=moe_ffn(n_experts=40, expert_size=512, k=8,
                    selector_activation="softmax", renormalize=True,
                    glu_experts=True, reg_kind="switch", reg_gamma=0.01,
                    dispatch="einsum"),
        tie_embeddings=True,
    )


@register("llama4-scout-17b-a16e")
def llama4_scout() -> ModelConfig:
    """[moe] MoE 16 experts top-1 + shared expert, early fusion. hf:meta-llama."""
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
        vocab_size=202048,
        attention=AttentionConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                                  rope_theta=500000.0),
        ffn=moe_ffn(n_experts=16, expert_size=8192, k=1,
                    selector_activation="sigmoid", glu_experts=True,
                    n_shared_experts=1, reg_kind="switch", reg_gamma=0.01,
                    dispatch="einsum"),
    )


@register("pixtral-12b")
def pixtral_12b() -> ModelConfig:
    """[vlm] pixtral-ViT frontend (STUB: precomputed patch embeddings) + mistral-nemo
    backbone. hf:mistralai/Pixtral-12B-2409."""
    return ModelConfig(
        name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
        vocab_size=131072,
        attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                                  rope_theta=1e6),
        ffn=FFNConfig(kind="glu", d_ff=14336, activation="silu"),
        n_vision_tokens=256,    # stub: one 256-token image prefix
    )


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    """[hybrid] Mamba2 backbone + shared attention+MLP block applied periodically.
    arXiv:2411.15242. 81 layer slots; every 6th slot applies the *shared* block."""
    pat = tuple(
        [BlockSpecEntry(mixer="ssm", ffn="none")] * 5
        + [BlockSpecEntry(mixer="shared_attn", ffn="shared_ffn")]
    )
    return ModelConfig(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        vocab_size=32000,
        attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=112),
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
        ffn=FFNConfig(kind="glu", d_ff=14336, activation="gelu"),
        pattern=pat, tie_embeddings=True, subquadratic=True,
    )


@register("deepseek-coder-33b")
def deepseek_coder_33b() -> ModelConfig:
    """[dense] llama-arch. arXiv:2401.14196."""
    return ModelConfig(
        name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
        vocab_size=32256,
        attention=AttentionConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                                  rope_theta=100000.0),
        ffn=FFNConfig(kind="glu", d_ff=19200, activation="silu"),
    )


@register("llama3-8b")
def llama3_8b() -> ModelConfig:
    """[dense] GQA, 128k vocab. arXiv:2407.21783."""
    return ModelConfig(
        name="llama3-8b", family="dense", n_layers=32, d_model=4096,
        vocab_size=128256,
        attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                                  rope_theta=500000.0),
        ffn=FFNConfig(kind="glu", d_ff=14336, activation="silu"),
    )


@register("gemma3-27b")
def gemma3_27b() -> ModelConfig:
    """[dense] 5:1 local:global attention, 128k ctx. hf:google/gemma-3."""
    pat = tuple(
        [BlockSpecEntry(mixer="attn", ffn="ffn", attn_kind="local")] * 5
        + [BlockSpecEntry(mixer="attn", ffn="ffn", attn_kind="global")]
    )
    return ModelConfig(
        name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
        vocab_size=262144,
        attention=AttentionConfig(n_heads=32, n_kv_heads=16, head_dim=128,
                                  window=1024, qk_norm=True),
        ffn=FFNConfig(kind="glu", d_ff=21504, activation="gelu"),
        pattern=pat, tie_embeddings=True, logit_softcap=30.0,
    )


@register("minicpm-2b")
def minicpm_2b() -> ModelConfig:
    """[dense] WSD schedule, llama-like arch. arXiv:2404.06395."""
    return ModelConfig(
        name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
        vocab_size=122753,
        attention=AttentionConfig(n_heads=36, n_kv_heads=36, head_dim=64),
        ffn=FFNConfig(kind="glu", d_ff=5760, activation="silu"),
        tie_embeddings=True,
    )


@register("whisper-tiny")
def whisper_tiny() -> ModelConfig:
    """[audio] enc-dec; conv frontend STUBBED (precomputed frame embeddings).
    arXiv:2212.04356."""
    return ModelConfig(
        name="whisper-tiny", family="audio", n_layers=4, d_model=384,
        vocab_size=51865, norm="layernorm", pos_encoding="learned",
        attention=AttentionConfig(n_heads=6, n_kv_heads=6, head_dim=64),
        ffn=FFNConfig(kind="dense", d_ff=1536, activation="gelu"),
        is_encoder_decoder=True, n_encoder_layers=4, n_audio_frames=1500,
        max_seq_len=32768 + 8, tie_embeddings=True,
    )


# ---------------------------------------------------------------------------
# Paper configs (Tab. 8 / Tab. 9)
# ---------------------------------------------------------------------------

def _paper_base(d_model, d_ff, n_layers, n_heads, head_dim, ctx, vocab) -> ModelConfig:
    return ModelConfig(
        name="paper", family="dense", n_layers=n_layers, d_model=d_model,
        vocab_size=vocab, norm="layernorm", pos_encoding="xl_rel",
        attention=AttentionConfig(n_heads=n_heads, n_kv_heads=n_heads,
                                  head_dim=head_dim, kind="xl_rel"),
        ffn=FFNConfig(kind="dense", d_ff=d_ff, activation="relu"),
        xl_memory=ctx, max_seq_len=4 * ctx, dropout=0.1,
    )


@register("wt103-47m-dense")
def wt103_small_dense() -> ModelConfig:
    # Tab. 8 row 1: 47M, d_model 412, d_ff 2053, 16L, 10H, head 41, ctx 256, SP vocab.
    return _paper_base(412, 2053, 16, 10, 41, 256, 8000).override(name="wt103-47m-dense")


@register("wt103-47m-moe")
def wt103_small_moe() -> ModelConfig:
    # Tab. 9: N_E=16, G=128, K=4, gamma=1e-3, no expert dropout.
    base = wt103_small_dense()
    return base.with_ffn(moe_ffn(16, 128, 4, reg_gamma=1e-3, reg_kind="entropy",
                                 dispatch="sort")).override(name="wt103-47m-moe")


@register("wt103-262m-dense")
def wt103_big_dense() -> ModelConfig:
    return _paper_base(1024, 4110, 18, 16, 64, 512, 8000).override(
        name="wt103-262m-dense", dropout=0.2)


@register("wt103-262m-moe")
def wt103_big_moe() -> ModelConfig:
    base = wt103_big_dense()
    return base.with_ffn(moe_ffn(32, 128, 4, expert_dropout=0.2, reg_gamma=1e-3,
                                 reg_kind="entropy", dispatch="sort")).override(
        name="wt103-262m-moe")


@register("enwik8-41m-dense")
def enwik8_dense() -> ModelConfig:
    return _paper_base(512, 2053, 12, 8, 64, 512, 256).override(name="enwik8-41m-dense")


@register("enwik8-41m-moe")
def enwik8_moe() -> ModelConfig:
    base = enwik8_dense()
    return base.with_ffn(moe_ffn(16, 128, 4, expert_dropout=0.05, reg_gamma=1e-4,
                                 reg_kind="entropy", dispatch="sort")).override(
        name="enwik8-41m-moe")


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants: same family, tiny sizes, runnable on CPU.
# ---------------------------------------------------------------------------

def reduced(name: str) -> ModelConfig:
    """A tiny config of the same family as `name` for CPU smoke tests."""
    cfg = get_config(name)
    kw = dict(
        n_layers=min(cfg.n_layers, 3 if not cfg.pattern else len(cfg.pattern)),
        d_model=64, vocab_size=256, max_seq_len=512,
    )
    if cfg.attention.n_heads:
        kw["attention"] = AttentionConfig(
            n_heads=4, n_kv_heads=2 if cfg.attention.n_kv_heads < cfg.attention.n_heads else 4,
            head_dim=16, kind=cfg.attention.kind, window=32,
            qk_norm=cfg.attention.qk_norm, kv_chunk=64)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32)
    f = cfg.ffn
    if f.kind in ("sigma_moe", "switch", "sbase", "noisy_topk"):
        # dispatch="sort": dropless, so decode == full forward bit-for-bit in tests
        # (capacity-based paths legitimately drop different tokens per call shape).
        kw["ffn"] = moe_ffn(4, 32, min(f.k, 2),
                            selector_activation=f.selector_activation,
                            renormalize=f.renormalize, glu_experts=f.glu_experts,
                            n_shared_experts=f.n_shared_experts, reg_kind=f.reg_kind,
                            reg_gamma=f.reg_gamma, dispatch="sort")
    elif f.kind in ("dense", "glu"):
        kw["ffn"] = FFNConfig(kind=f.kind, d_ff=128, activation=f.activation)
    elif f.kind == "pkm":
        kw["ffn"] = FFNConfig(kind="pkm", n_subkeys=8, pkm_heads=2, pkm_knn=4)
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = 2
        kw["n_audio_frames"] = 32
    if cfg.n_vision_tokens:
        kw["n_vision_tokens"] = 8
    if cfg.xl_memory:
        kw["xl_memory"] = 32
    return cfg.override(**kw)


ASSIGNED_ARCHS = [
    "mamba2-370m", "granite-moe-3b-a800m", "llama4-scout-17b-a16e", "pixtral-12b",
    "zamba2-7b", "deepseek-coder-33b", "llama3-8b", "gemma3-27b", "minicpm-2b",
    "whisper-tiny",
]
