"""Config system: typed dataclasses for model / FFN / shape / mesh / run configs.

Design notes
------------
- Everything is a frozen dataclass; `replace(cfg, **kw)` / `cfg.override(**kw)` produce
  variants. Configs are pure data — no jax imports here, so importing a config never
  touches device state (required for the dry-run XLA_FLAGS dance).
- The registry maps ``--arch <id>`` strings to ModelConfig factories.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# FFN (the paper's subject)
# ---------------------------------------------------------------------------

FFN_KINDS = ("dense", "glu", "topk", "pkm", "sigma_moe", "switch", "sbase", "noisy_topk", "none")

# Kernel lowering of the planned execution layer (core/dispatch.py):
#   auto          defer to kernels.ops.default_impl() (pallas_fused on TPU,
#                 ragged elsewhere) — the production setting.
#   pallas_fused  fused streamed kernels (epilogues in-kernel); *_interpret
#   pallas        unfused planned kernels;                       variants run
#   ragged        lax.ragged_dot grouped matmul (MoE sort path)  the pallas
#   einsum        XLA take+einsum rung (weighted value sums)     kernels in
#   dense         bypass the planned layer entirely: full down-  interpret
#                 projection / dense 4-D value gather (oracle    mode (tests)
#                 reference for tests and ablations)
FFN_IMPLS = ("auto", "dense", "einsum", "ragged", "ref", "pallas",
             "pallas_interpret", "pallas_fused", "pallas_fused_interpret")


@dataclass(frozen=True)
class FFNConfig:
    """Configuration of one feedforward block (the paper's subject).

    kind:
      dense      -- y = W2 relu(W1 x)                     (paper Eq. 1-2)
      glu        -- y = W2 (act(W1 x) * W3 x)             (llama-family)
      topk       -- dense with top-K activation           (paper Sec. 3.1)
      pkm        -- product-key memory                    (paper Sec. 3.2)
      sigma_moe  -- the paper's sigma-MoE                 (paper Sec. 5)
      switch     -- Switch-Transformer routing            (paper Sec. 4)
      sbase      -- S-BASE (Sinkhorn)                     (paper Sec. 4)
      noisy_topk -- Shazeer 2017 sparsely-gated           (paper Sec. 4)
      none       -- no FFN at all (mamba2 blocks)
    """
    kind: str = "dense"
    d_ff: int = 0                      # total d_ff (= G * n_experts for MoE)
    activation: str = "relu"           # relu | gelu | silu | softmax (PKM ablation)
    # --- MoE family ---
    n_experts: int = 0                 # N_E
    expert_size: int = 0               # G (group size); d_ff = G * N_E
    k: int = 0                         # top-K experts
    selector_activation: str = "sigmoid"   # sigmoid | softmax | softmax_pre_topk
    renormalize: bool = False          # re-normalize scores after top-K
    expert_dropout: float = 0.0        # delta (Eq. 22)
    reg_gamma: float = 0.0             # entropy reg strength (Eq. 21)
    reg_kind: str = "entropy"          # entropy | switch | cv | none
    capacity_factor: float = 1.25      # mu, for capacity-based dispatch
    dispatch: str = "einsum"           # einsum | sort  (sort == CVMM path)
    impl: str = "auto"                 # kernel lowering, see FFN_IMPLS
    sigma_moe_init: bool = True        # paper's dense-equivalent init
    n_shared_experts: int = 0          # llama4-style always-on shared expert
    glu_experts: bool = False          # experts use GLU (for llama-family MoE)
    sinkhorn_iters: int = 8
    noise_std: float = 1.0             # noisy_topk
    # --- top-K activation (Sec 3.1) ---
    topk_k: int = 0
    # --- PKM (Sec 3.2) ---
    pkm_heads: int = 4
    pkm_knn: int = 32                  # K per head
    n_subkeys: int = 0                 # sqrt(d_ff); n_values = n_subkeys**2
    n_candidates: int = 0              # C: two-stage top-C per sub-key half
    #                                    (0 => C = pkm_knn, the minimum legal C)

    @property
    def n_values(self) -> int:
        """DERIVED from n_subkeys (the single source of truth): the PKM value
        table is always (n_subkeys**2, d_model), and init_pkm scales by this
        same quantity — a stale d_ff cannot silently mis-scale the paper's
        dense-equivalent value init (validated below)."""
        return self.n_subkeys * self.n_subkeys

    @property
    def pkm_candidates(self) -> int:
        """Effective two-stage candidate width C: top-C per sub-key half, the
        C*C candidate grid is re-scored to the final top-K. The true top-K of
        the full n_subkeys**2 grid is provably contained in the grid iff
        C >= K, so C defaults to pkm_knn when n_candidates is unset."""
        return self.n_candidates or self.pkm_knn

    def validate(self) -> None:
        assert self.kind in FFN_KINDS, self.kind
        assert self.impl in FFN_IMPLS, self.impl
        if self.kind in ("sigma_moe", "switch", "sbase", "noisy_topk"):
            assert self.n_experts > 0 and self.expert_size > 0 and self.k > 0
        if self.kind == "pkm":
            assert self.n_subkeys > 1
            # d_ff, when set for parameter accounting, must agree with the
            # derived value count — PKM's d_ff IS n_subkeys**2 (paper Sec 3.2).
            assert self.d_ff in (0, self.n_values), \
                f"pkm d_ff={self.d_ff} != n_subkeys**2={self.n_values}"
            # Two-stage candidate width: top-K over the C*C candidate grid
            # only provably equals the full top-K when each half contributes
            # at least K candidates (containment needs C >= K), and a C wider
            # than n_subkeys is impossible (each half only has n_subkeys
            # scores to take top-C from). Unset (0) means C = pkm_knn, the
            # minimum legal width, so only an explicit value needs checking.
            if self.n_candidates:
                assert self.n_candidates >= self.pkm_knn, (
                    f"pkm n_candidates={self.n_candidates} < pkm_knn="
                    f"{self.pkm_knn}: the two-stage C*C candidate grid can "
                    f"only contain the true top-K when C >= K (set "
                    f"n_candidates >= pkm_knn, or 0 for C=K)")
                assert self.n_candidates <= self.n_subkeys, (
                    f"pkm n_candidates={self.n_candidates} > n_subkeys="
                    f"{self.n_subkeys}: each half only has n_subkeys scores "
                    f"to take top-C from")
        if self.kind in ("dense", "glu", "topk"):
            assert self.d_ff > 0


def moe_ffn(n_experts: int, expert_size: int, k: int, **kw) -> FFNConfig:
    return FFNConfig(kind="sigma_moe", n_experts=n_experts, expert_size=expert_size,
                     k=k, d_ff=n_experts * expert_size, **kw)


# ---------------------------------------------------------------------------
# Attention / block / model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int = 0
    n_kv_heads: int = 0                # GQA; == n_heads for MHA
    head_dim: int = 0
    rope_theta: float = 10000.0
    kind: str = "global"               # global | local (sliding window) | xl_rel
    window: int = 0                    # sliding-window size for kind=local
    causal: bool = True
    qk_norm: bool = False
    softmax_scale: Optional[float] = None
    kv_chunk: int = 2048               # flash-attention KV chunk (pure-JAX path)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block config."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                   # SSD chunk length
    n_groups: int = 1                  # B/C groups (like GQA for SSM)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class BlockSpecEntry:
    """One entry of a layer pattern: which mixer + which ffn."""
    mixer: str                          # "attn" | "ssm" | "shared_attn"
    ffn: str = "ffn"                    # "ffn" | "none" | "shared_ffn"
    attn_kind: str = ""                 # override attention kind ("local"/"global")


@dataclass(frozen=True)
class ModelConfig:
    name: str = ""
    family: str = "dense"              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 0
    d_model: int = 0
    vocab_size: int = 0
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    ffn: FFNConfig = field(default_factory=FFNConfig)
    ssm: Optional[SSMConfig] = None
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dropout: float = 0.0
    max_seq_len: int = 131072
    dtype: str = "bfloat16"            # compute dtype
    param_dtype: str = "float32"       # master dtype
    # Layer pattern. Empty => uniform [attn + ffn] * n_layers.
    # (pattern, repeat) pairs: pattern repeated; remainder handled by model builder.
    pattern: Tuple[BlockSpecEntry, ...] = ()
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500         # stub frontend output length
    # vlm: stub patch embeddings prepended — only affects input_specs
    n_vision_tokens: int = 0
    # XL-style segment memory (paper repro configs)
    xl_memory: int = 0
    # positional encoding
    pos_encoding: str = "rope"         # rope | xl_rel | learned | none
    # logit softcap (gemma-style), 0 = off
    logit_softcap: float = 0.0
    # sub-quadratic? (decides long_500k applicability)
    subquadratic: bool = False

    # ---- derived ----
    @property
    def supports_decode(self) -> bool:
        return True                     # all our archs have a decoder

    def override(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_ffn(self, ffn: FFNConfig) -> "ModelConfig":
        return dataclasses.replace(self, ffn=ffn)

    def layer_pattern(self) -> List[BlockSpecEntry]:
        """Expanded per-layer pattern of length n_layers."""
        if not self.pattern:
            return [BlockSpecEntry(mixer="attn", ffn="ffn")] * self.n_layers
        out: List[BlockSpecEntry] = []
        i = 0
        while len(out) < self.n_layers:
            out.append(self.pattern[i % len(self.pattern)])
            i += 1
        return out[: self.n_layers]

    # ---- parameter counting (analytic; used for roofline MODEL_FLOPS) ----
    def ffn_params(self, ffn: Optional[FFNConfig] = None) -> Tuple[int, int]:
        """(total, active) parameter counts of one FFN block."""
        f = ffn or self.ffn
        d = self.d_model
        if f.kind == "none":
            return 0, 0
        if f.kind in ("dense", "topk"):
            p = 2 * d * f.d_ff
            active = 2 * d * (f.topk_k if (f.kind == "topk" and f.topk_k) else f.d_ff)
            # top-k still computes full up-projection (paper Sec 3.1)
            if f.kind == "topk":
                active = d * f.d_ff + d * (f.topk_k or f.d_ff)
            return p, active
        if f.kind == "glu":
            return 3 * d * f.d_ff, 3 * d * f.d_ff
        if f.kind == "pkm":
            p = 2 * f.n_subkeys * (d // 2) + f.n_values * d
            active = 2 * f.n_subkeys * (d // 2) + f.pkm_heads * f.pkm_knn * d
            return p, active
        # MoE family
        per_expert = (3 if f.glu_experts else 2) * d * f.expert_size
        p = f.n_experts * per_expert + f.n_experts * d           # + router
        p += f.n_shared_experts * per_expert
        active = (f.k + f.n_shared_experts) * per_expert + f.n_experts * d
        return p, active

    def attn_params(self) -> int:
        a = self.attention
        d = self.d_model
        p = d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
        if a.kind == "xl_rel":
            # Transformer-XL: relative-position projection W_r (+ small u/v biases).
            p += d * a.q_dim + 2 * a.q_dim
        return p

    def ssm_params(self) -> int:
        if self.ssm is None:
            return 0
        s = self.ssm
        d = self.d_model
        din = s.d_inner(d)
        nh = s.n_heads(d)
        # in_proj: x->(z, x, B, C, dt); conv; A, D, dt_bias; norm; out_proj
        conv_dim = din + 2 * s.n_groups * s.d_state
        in_proj = d * (2 * din + 2 * s.n_groups * s.d_state + nh)
        return in_proj + conv_dim * s.d_conv + 3 * nh + din + din * d

    def param_counts(self) -> Dict[str, int]:
        """Analytic totals: {'total': N, 'active': N_active, 'embedding': ...}."""
        d = self.d_model
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        total = emb + head
        body_total = 0
        body_active = 0
        shared_attn_counted = False
        shared_ffn_counted = False
        for entry in self.layer_pattern():
            if entry.mixer == "attn":
                body_total += self.attn_params()
                body_active += self.attn_params()
            elif entry.mixer == "shared_attn":
                if not shared_attn_counted:
                    body_total += self.attn_params()
                    shared_attn_counted = True
                body_active += self.attn_params()
            elif entry.mixer == "ssm":
                body_total += self.ssm_params()
                body_active += self.ssm_params()
            if entry.ffn == "ffn":
                t, a = self.ffn_params()
                body_total += t
                body_active += a
            elif entry.ffn == "shared_ffn":
                t, a = self.ffn_params()
                if not shared_ffn_counted:
                    body_total += t
                    shared_ffn_counted = True
                body_active += a
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn (non-causal), plus decoder cross-attn
            enc = self.n_encoder_layers * (self.attn_params() + self.ffn_params()[0])
            cross = self.n_layers * self.attn_params()
            body_total += enc + cross
            body_active += enc + cross
        total += body_total
        # "active" params per token: unembedding matmul + body active path.
        # (Embedding lookup is a gather, conventionally excluded from 6ND.)
        return {
            "total": total,
            "active": head + body_active,
            "embedding": emb + head,
            "body": body_total,
            "body_active": body_active,
        }


# ---------------------------------------------------------------------------
# Input shapes (assigned set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # train | prefill | decode

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / run configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    def axis_size(self, name: str) -> int:
        return self.shape[self.axes.index(name)] if name in self.axes else 1


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 2.5e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.25
    schedule: str = "cosine"           # cosine | wsd | constant
    warmup_steps: int = 0
    total_steps: int = 100_000
    final_lr_ratio: float = 0.0
    grad_accum: int = 1
    grad_compression: str = "none"     # none | bf16 | int8  (error-feedback)


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    seq_len: int = 256
    global_batch: int = 64
    steps: int = 100
    seed: int = 0
    log_every: int = 10
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    remat: str = "full"                # full | dots | none
    sequence_parallel: bool = False    # SP sharding constraint on residual stream
    chunked_ce_chunks: int = 1         # >1 enables chunked cross-entropy
    async_checkpoint: bool = True
    data: str = "synthetic"            # synthetic | <path to text file>
