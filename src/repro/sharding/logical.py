"""Logical-axis sharding: parameter/activation names -> PartitionSpec via rule tables.

Every parameter leaf in this repo has a well-known name (w1, wq, emb, ...) whose layout
is identified by (name, rank). ``PARAM_AXES`` maps those to *logical* axis names;
``LogicalRules`` maps logical names to mesh axes. Scan-stacked parameters have a
leading 'layers' dimension, handled by rank-1 lookup.

Two built-in rule sets:
  TRAIN_RULES  FSDP ('embed'->data) + TP ('ffn','heads','experts','vocab'->model)
               + DP batch over (pod, data). Optimizer state inherits param specs.
  SERVE_RULES  TP-only weights (latency path, no per-layer all-gathers), KV cache and
               batch over (pod, data).

Duplicate-mesh-axis resolution
------------------------------
A NamedSharding may map each mesh axis to at most ONE positional dimension, but a
logical-axes tuple can legally rule two of its entries onto the same mesh axis (the
seed bug: PKM ``keys_a``/``keys_b`` were ``("heads", "embed", "pkm_keys")`` with both
'heads' and 'pkm_keys' ruled to 'model' -> ``PartitionSpec(None, 'model', 'data',
'model')`` crashed every ``--ffn pkm`` mesh run at sharding setup). ``spec_for_axes``
therefore resolves duplicates deterministically: the FIRST (leftmost) occurrence of a
mesh axis keeps it, every repeat is dropped to None (for tuple rules, the repeated
member is removed from the tuple). Tests run under STRICT mode
(``strict_duplicate_check()`` context manager, or ``REPRO_STRICT_SHARDING=1``), where
a duplicate instead raises ``DuplicateMeshAxisError`` naming the leaf path, the mesh
axis, and the two conflicting logical axes — so a bad ``PARAM_AXES``/rules entry fails
the sweep in tests/test_sharding_multidev.py instead of shipping a silent layout.
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .context import current_mesh

Axis = Union[None, str, Tuple[str, ...]]
LogicalRules = Dict[str, Axis]


class DuplicateMeshAxisError(ValueError):
    """Strict mode: one logical-axes tuple ruled a mesh axis onto two dims."""


_strict_state = threading.local()


def _strict_enabled(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    flag = getattr(_strict_state, "strict", None)
    if flag is not None:
        return flag
    return os.environ.get("REPRO_STRICT_SHARDING", "") not in ("", "0")


@contextlib.contextmanager
def strict_duplicate_check(enabled: bool = True):
    """Within this context, duplicate mesh axes raise instead of resolving."""
    prev = getattr(_strict_state, "strict", None)
    _strict_state.strict = enabled
    try:
        yield
    finally:
        _strict_state.strict = prev

TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,                 # flipped to "model" by sequence_parallel (perf pass)
    "vocab": "model",
    "embed": "data",             # FSDP: gathered per layer inside the scan
    "embed_nofsdp": None,
    "ffn": "model",
    "expert_ff": None,           # EP shards experts; flip to "model" for TP-in-expert
    "experts": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "layers": None,
    "pkm_values": "model",
    "pkm_keys": "model",
    "pkm_heads": None,           # PKM heads stay local: 'pkm_keys' owns 'model'
                                 # for the key tables (two dims on one mesh axis
                                 # is illegal — see header)
    "shared_experts": None,      # shared-expert count (usually 1) stays local:
                                 # 'ffn' owns 'model' for those leaves
    "pod_err": "pod",            # pod-stacked error-feedback state (optim/compress)
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "pos": None,
}

SERVE_RULES: LogicalRules = dict(
    TRAIN_RULES,
    embed=None,                  # no FSDP at inference
    seq=None,
    kv_seq=None,                 # cache seq; flipped to "model" by serve_rules_for
)

# Sequence parallelism: residual-stream activations between blocks are sharded over
# the TP axis along seq (Korthikanti et al.); cuts stored-activation memory by the
# TP degree at the cost of gather/scatter at block boundaries.
SP_RULES: LogicalRules = dict(TRAIN_RULES, seq="model")


def serve_rules_for(n_kv_heads: int, model_axis_size: int) -> LogicalRules:
    """Cache sharding policy: shard KV heads over TP when divisible; otherwise
    shard the cache SEQUENCE over TP (context-parallel decode: the softmax
    reduction over a sharded seq becomes an SPMD psum). Without this, a kv=8
    cache on 16-way TP replicates -- 17 GB/chip for llama3 decode_32k, over HBM."""
    if n_kv_heads and model_axis_size and n_kv_heads % model_axis_size == 0:
        return SERVE_RULES
    return dict(SERVE_RULES, kv_seq="model", kv_heads=None)

# (leaf name, logical rank) -> logical axes. Rank excludes the stacked 'layers' dim.
PARAM_AXES: Dict[Tuple[str, int], Tuple[str, ...]] = {
    # embeddings / head
    ("emb", 2): ("vocab", "embed"),          # 2-D sharded: TP x FSDP
    ("pos_emb", 2): ("pos", "embed"),
    ("unembed", 2): ("embed", "vocab"),
    # norms
    ("scale", 1): ("embed_nofsdp",),
    ("bias", 1): ("embed_nofsdp",),
    # attention
    ("wq", 2): ("embed", "qkv"),
    ("wk", 2): ("embed", "qkv"),
    ("wv", 2): ("embed", "qkv"),
    ("wo", 2): ("qkv", "embed"),
    ("w_r", 2): ("embed", "qkv"),        # XL relative-position projection
    ("u_bias", 2): ("heads", None),
    ("v_bias", 2): ("heads", None),
    ("q_scale", 1): (None,),
    ("k_scale", 1): (None,),
    # dense/glu ffn
    ("w1", 2): ("embed", "ffn"),
    ("w2", 2): ("ffn", "embed"),
    ("w3", 2): ("embed", "ffn"),
    # moe (rank-3 experts; EP owns the model axis, expert_ff stays local)
    ("we1", 3): ("experts", "embed", "expert_ff"),
    ("we1g", 3): ("experts", "embed", "expert_ff"),
    ("we2", 3): ("experts", "expert_ff", "embed"),
    # shared experts: the count (usually 1) stays local under 'shared_experts'
    # so 'ffn' alone claims 'model' — ("experts", ..., "ffn") put 'model' on
    # two dims of one leaf, the same class of bug as the pkm key tables.
    ("shared_w1", 3): ("shared_experts", "embed", "ffn"),
    ("shared_w1g", 3): ("shared_experts", "embed", "ffn"),
    ("shared_w2", 3): ("shared_experts", "ffn", "embed"),
    ("router", 2): ("embed", None),
    ("router_noise", 2): ("embed", None),
    # pkm (heads local — 'heads' and 'pkm_keys' both ruled to 'model' was the
    # seed duplicate-axis crash; the key dim is the one worth sharding)
    ("keys_a", 3): ("pkm_heads", "embed", "pkm_keys"),
    ("keys_b", 3): ("pkm_heads", "embed", "pkm_keys"),
    ("values", 2): ("pkm_values", "embed"),
    # mamba2 / ssd
    ("in_proj", 2): ("embed", "ssm_inner"),
    ("out_proj", 2): ("ssm_inner", "embed"),
    ("conv_w", 2): ("ssm_inner", "conv"),
    ("conv_b", 1): ("ssm_inner",),
    ("A_log", 1): ("ssm_inner",),
    ("D", 1): ("ssm_inner",),
    ("dt_bias", 1): ("ssm_inner",),
    # KV / SSM caches (serving state)
    ("k", 4): ("batch", "kv_seq", "kv_heads", None),
    ("v", 4): ("batch", "kv_seq", "kv_heads", None),
    ("state", 4): ("batch", "heads", None, None),
    ("conv", 3): ("batch", None, "ssm_inner"),
    # batch inputs
    ("tokens", 2): ("batch", None),
    ("token", 1): ("batch",),
    ("patches", 3): ("batch", None, None),
    ("frames", 3): ("batch", None, None),
}


def spec_for_axes(axes: Tuple[Optional[str], ...], rules: LogicalRules,
                  mesh: Optional[Mesh], *, strict: Optional[bool] = None,
                  path: str = "") -> P:
    """Logical axes tuple -> PartitionSpec, dropping mesh axes that don't exist.

    A mesh axis appearing twice (two logical axes ruled onto it, or twice within
    one tuple rule) resolves deterministically: first occurrence wins, repeats
    drop to None. Strict mode (``strict_duplicate_check()`` /
    ``REPRO_STRICT_SHARDING=1``) raises ``DuplicateMeshAxisError`` instead,
    naming the leaf ``path`` and both conflicting logical axes."""
    names = set(mesh.axis_names) if mesh is not None else set()
    out = []
    used: Dict[str, Optional[str]] = {}   # mesh axis -> logical axis that claimed it
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        members = () if m is None else (m if isinstance(m, tuple) else (m,))
        kept = []
        for a in members:
            if a not in names:
                continue
            if a in used:
                if _strict_enabled(strict):
                    raise DuplicateMeshAxisError(
                        f"mesh axis '{a}' mapped to two dims of one leaf"
                        f"{' at ' + path if path else ''}: logical axis "
                        f"'{used[a]}' already claimed it, '{ax}' repeats it "
                        f"(logical axes {axes}). Fix PARAM_AXES/rules so each "
                        f"mesh axis shards at most one dim per leaf.")
                continue                   # keep first occurrence, drop repeat
            kept.append(a)
            used[a] = ax
        if not kept:
            out.append(None)
        elif isinstance(m, tuple):
            out.append(tuple(kept))
        else:
            out.append(kept[0])
    return P(*out)


def _leaf_axes(name: str, rank: int) -> Tuple[Optional[str], ...]:
    if (name, rank) in PARAM_AXES:
        return PARAM_AXES[(name, rank)]
    if (name, rank - 1) in PARAM_AXES:                 # scan-stacked: leading layers
        return ("layers",) + PARAM_AXES[(name, rank - 1)]
    if (name, rank - 2) in PARAM_AXES:                 # doubly stacked (superblocks)
        return ("layers", "layers") + PARAM_AXES[(name, rank - 2)]
    return (None,) * rank                              # replicate unknown leaves


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for entry in path:
        key = getattr(entry, "key", None) or getattr(entry, "name", None)
        if isinstance(key, str):
            out.append(key)
    return tuple(out)


def spec_for(path, leaf, rules: LogicalRules, mesh: Optional[Mesh],
             strict: Optional[bool] = None) -> P:
    keys = _path_keys(path)
    name = keys[-1] if keys else None
    rank = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    axes = _leaf_axes(name or "", rank)
    # Pod-stacked error-feedback state (optim/compress.init_compression_state
    # with pod>1): leaves under the "err" subtree carry a leading per-pod dim
    # on top of the param layout — shard it over the DCN 'pod' axis so each
    # pod stores only its own quantization residual.
    if keys and keys[0] == "err" and rank >= 1:
        inner = _leaf_axes(name or "", rank - 1)
        if (name, rank - 1) in PARAM_AXES or any(a is not None for a in inner):
            axes = ("pod_err",) + inner
    spec = spec_for_axes(axes, rules, mesh, strict=strict,
                         path=jax.tree_util.keystr(path))
    # jax.Array inputs require evenly divisible shardings: drop (replicate) any axis
    # that does not divide its dimension (e.g. whisper's vocab 51865 over 16-way TP,
    # 8 KV heads over 16-way TP). GSPMD-internal constraints may still pad; inputs
    # cannot.
    shape = getattr(leaf, "shape", None)
    if shape is not None and mesh is not None:
        fixed = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * (rank - len(spec))):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        spec = P(*fixed)
    return spec


def tree_shardings(tree, mesh: Mesh, rules: LogicalRules):
    """Pytree of NamedShardings matching `tree` (arrays or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf, rules, mesh)),
        tree)


def tree_specs(tree, rules: LogicalRules, mesh: Optional[Mesh]):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path, leaf, rules, mesh), tree)


def logical_sharding(axes: Tuple[Optional[str], ...], rules: LogicalRules,
                     mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for_axes(axes, rules, mesh))


def with_logical_constraint(x: jax.Array, axes: Tuple[Optional[str], ...],
                            rules: LogicalRules = TRAIN_RULES) -> jax.Array:
    """Sharding-constrain an activation by logical axes; no-op without a mesh."""
    sh = logical_sharding(axes, rules)
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
