from .context import current_mesh, mesh_context, axis_size
from .logical import (LogicalRules, TRAIN_RULES, SERVE_RULES, logical_sharding,
                      serve_rules_for, spec_for, tree_shardings,
                      with_logical_constraint)

__all__ = [
    "current_mesh", "mesh_context", "axis_size", "LogicalRules", "TRAIN_RULES",
    "SERVE_RULES", "logical_sharding", "serve_rules_for", "spec_for", "tree_shardings",
    "with_logical_constraint",
]
