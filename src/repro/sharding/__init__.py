from .context import current_mesh, mesh_context, axis_size
from .logical import (DuplicateMeshAxisError, LogicalRules, TRAIN_RULES,
                      SERVE_RULES, logical_sharding, serve_rules_for, spec_for,
                      spec_for_axes, strict_duplicate_check, tree_shardings,
                      with_logical_constraint)

__all__ = [
    "current_mesh", "mesh_context", "axis_size", "DuplicateMeshAxisError",
    "LogicalRules", "TRAIN_RULES", "SERVE_RULES", "logical_sharding",
    "serve_rules_for", "spec_for", "spec_for_axes", "strict_duplicate_check",
    "tree_shardings", "with_logical_constraint",
]
