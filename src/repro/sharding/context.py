"""Process-wide mesh context.

Model code never builds meshes; the launcher installs one here. When no mesh is
installed (unit tests, single-host runs) the shard_map paths fall back to local
computation.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from jax.sharding import Mesh

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev
