from . import autotune, compat, ops, ref

__all__ = ["autotune", "compat", "ops", "ref"]
