from . import compat, ops, ref

__all__ = ["compat", "ops", "ref"]
