"""Pallas TPU flash-attention (forward) kernel.

Grid: (batch*kv_heads*q_groups, q_blocks); the kernel body runs an online-softmax
loop over KV blocks held in VMEM. Blocks are MXU-aligned (BQ x D, BK x D); the
(BQ, BK) probability tile never leaves VMEM — the memory behaviour the pure-JAX
chunked path (models/attention.py) emulates at the XLA level.

Backward uses the differentiable pure-JAX path via custom_vjp (recompute-based, the
standard flash trade). ops-level entry: ``flash_fwd`` in kernels/ops.py style —
here self-contained as ``flash_attention_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import tpu_compiler_params

BQ = 128
BK = 128
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
            kv_len: int):
    # q_ref: (1, BQ, D); k_ref/v_ref: (1, SK, D); o_ref: (1, BQ, D)
    qb = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale
    sk = k_ref.shape[1]
    n_kb = sk // BK

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(kb * BK, BK), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kb * BK, BK), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BQ, BK)
        q_pos = qb * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
        k_pos = kb * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((BQ,), NEG, jnp.float32)
    l0 = jnp.zeros((BQ,), jnp.float32)
    a0 = jnp.zeros((BQ, q_ref.shape[2]), jnp.float32)
    # causal: KV blocks beyond this Q block contribute nothing; skip them.
    upper = n_kb if not causal else jnp.minimum(
        n_kb, (qb + 1) * BQ // BK + (1 if BQ % BK else 0)).astype(jnp.int32)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float,
                           interpret: bool = False) -> jax.Array:
    """q (B, Sq, H, D), k/v (B, Sk, KV, D) with H % KV == 0; Sq/Sk padded to 128
    internally. Forward only (wrap with custom_vjp at the call site if training)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    grp = h // kvh
    from ..common import round_up
    sq_p, sk_p = round_up(sq, BQ), round_up(sk, BK)
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    # layout: (B*H, S, D) with q head order grouped by kv head
    qf = qp.reshape(b, sq_p, kvh, grp, d).transpose(0, 2, 3, 1, 4) \
           .reshape(b * kvh * grp, sq_p, d)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * kvh, sk_p, d)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * kvh, sk_p, d)

    grid = (b * kvh * grp, sq_p // BQ)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, kv_len=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk_p, d), lambda i, j: (i // grp, 0, 0)),
            pl.BlockSpec((1, sk_p, d), lambda i, j: (i // grp, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh * grp, sq_p, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf[:, None].reshape(b * kvh * grp, sq_p, d), kf, vf)
    out = out.reshape(b, kvh, grp, sq_p, d).transpose(0, 3, 1, 2, 4) \
             .reshape(b, sq_p, h, d)
    return out[:, :sq]
