"""Pure-jnp oracles for the CVMM (conditional vector-matrix multiply) kernels.

CVMM (paper Eq. 26): given rows V (N, M), per-row matrix selector S (N,) and matrices
W (E, M, L):  CVMM(V, S, W)[n] = V[n] @ W[S[n]].

The kernel-facing layout is *sorted-by-expert* with group_sizes (E,) summing to N
(the paper's CUDA kernel performs the same sort as preprocessing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def row_experts(group_sizes: jax.Array, n_rows: int) -> jax.Array:
    """Expert id of each sorted row: row i belongs to the group whose cumulative
    range contains i."""
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(n_rows), side="right")


def cvmm_ref(x: jax.Array, group_sizes: jax.Array, w: jax.Array) -> jax.Array:
    """(N, K) x (E,) x (E, K, L) -> (N, L); fp32 accumulation."""
    e = w.shape[0]
    re = row_experts(group_sizes, x.shape[0])
    onehot = jax.nn.one_hot(re, e, dtype=jnp.float32)
    out = jnp.einsum("nk,ekl,ne->nl", x.astype(jnp.float32),
                     w.astype(jnp.float32), onehot)
    return out.astype(x.dtype)


def cvmm_dw_ref(x: jax.Array, group_sizes: jax.Array, g: jax.Array,
                n_experts: int) -> jax.Array:
    """Grad wrt W: dW[e] = sum_{rows n of expert e} x[n]^T g[n].  (E, K, L), fp32."""
    re = row_experts(group_sizes, x.shape[0])
    onehot = jax.nn.one_hot(re, n_experts, dtype=jnp.float32)
    return jnp.einsum("nk,nl,ne->ekl", x.astype(jnp.float32),
                      g.astype(jnp.float32), onehot)
