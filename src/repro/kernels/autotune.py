"""Roofline-driven kernel autotuner with a persistent on-disk tile cache.

Every planned kernel family in cvmm.py (the fused w1 gather, the gate-epilogue
w2 / plain grouped GEMM, the streamed dW outer products, and the streamed row
gather behind ``ops.gathered_weighted_sum``) needs a tile choice whose working
set fits VMEM. This module is the single place those choices come from:

  heuristic (tuning disabled, the default)
      The zero-cost answer: enumerate every legal candidate — all multiples of
      ``LANE`` that divide the padded width and whose working set fits the
      budget, largest first — and take the first. For widths expressible by
      the old fixed (512, 384, 256, 128) ladder this picks the identical tile;
      for widths the ladder missed (e.g. n_pad=640, a multiple of 128 but of
      neither 384 nor 512) it now finds the larger dividing tile instead of
      collapsing to 128. No I/O, no benchmarking: interpret-mode CI behavior
      is byte-identical to the static pickers this replaces.

  tuned (``REPRO_AUTOTUNE=1`` or ``autotune.enable()``; pre-warm with
  ``python -m benchmarks.run --tune``)
      The same legal candidates (tile width x stream pipeline depth) are
      ranked by a roofline cost estimate — HBM bytes moved and MXU FLOPs per
      grid pass against the active ``roofline.analysis.Hardware`` model, plus
      a fixed per-grid-step overhead — the top ``TUNE_TOP_K`` survivors are
      micro-benchmarked once per (kernel, shape-class, dtype, backend) key,
      and the winner is persisted to an on-disk JSON cache. Streamed families
      are measured at a fixed mixed-contiguity routing (``run_class``
      "mixed": half contiguous run-batched chunks, half scattered single-row
      chunks) so the measurement exercises both ends of the DMA chunk-size
      classes.

Cache layout
------------
One JSON file per backend: ``<cache_dir>/<backend>.json`` where ``cache_dir``
is ``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune``. Schema::

    {"schema": 1, "backend": "tpu", "hardware": "tpu_v5e",
     "entries": {"<family>|<dim>=<val>|...": {
         "tiles": {"tm": 128, "tn": 512, ...}, "provenance": "tuned",
         "us": 123.4, "estimate_s": ..., "run_class": "mixed"}}}

Keys are the padded shape dims (already LANE-quantized, so they ARE the shape
classes) plus dtype byte width; the backend lives in the filename. Writers
merge with the on-disk state and publish via write-to-temp + atomic
``os.replace`` so concurrent tuners never clobber or tear the file.
Invalidation is graceful: unreadable files, wrong ``schema`` versions, and
malformed entries are discarded and rebuilt, never raised; a cached tile that
is no longer legal under the CURRENT budget (tests shrink it) is ignored and
retuned. ``STATS["microbench_calls"]`` counts real measurements — a warm
cache must re-run with the counter at zero (CI checks this).

The VMEM budget itself is derived here too (``default_vmem_budget``):
``KERNEL_VMEM_FRACTION`` of the active Hardware model's ``vmem_bytes``
(0.75 * 16 MiB = the 12 MiB cvmm.py used to hard-code), overridable via
``$REPRO_VMEM_BUDGET``. kernels/cvmm.py initializes its module-level
``VMEM_BUDGET`` from this and threads it into every query at call time, so
tests that monkeypatch ``cvmm.VMEM_BUDGET`` shrink every picker at once.
"""
from __future__ import annotations

import functools
import json
import os
import tempfile
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional

from ..roofline.analysis import Hardware, hardware_for

TM = 128            # row tile (MXU-aligned); the CvmmPlan layout bakes this
                    # in, so candidates with any other tm are illegal.
LANE = 128          # lane multiple for K / N tile widths

SCHEMA_VERSION = 1
DEFAULT_CACHE_DIR = "~/.cache/repro/autotune"
KERNEL_VMEM_FRACTION = 0.75   # 12 MiB of the 16 MiB/core VMEM: headroom for
                              # Mosaic's own scratch + scalar memory
TUNE_TOP_K = 3                # candidates surviving the roofline pruning
BENCH_ITERS = 3               # min-of-N timing per surviving candidate
M_REF_TILES = 8               # reference row-tile count for cost + bench
STEP_OVERHEAD_S = 2e-6        # fixed per-grid-step cost in the roofline model

# Stream pipeline depths each family's candidate enumerator may emit when
# tuning is enabled (disabled -> depth 2 only, the static heuristic). This
# table — not the enumerator bodies — is what repro.analysis reads to know
# which (family, depth) pairs need a hazard proof and a VMEM fit proof, so a
# new depth added here is automatically swept by both passes.
FAMILY_DEPTHS: Dict[str, tuple] = {
    "pick_tn": (),                # blocked GEMM: no gather stream
    "decode_gemm": (),            # same kernel, decode (tiny-M) shape-class
    "fused_w1": (2, 3),
    "streamed_dw": (2, 3),
    "gather": (2, 3, 4),          # bare gather is DMA-bound: depth 4 can pay
    "gather_dedup": (2, 3, 4),
}
SUPPORTED_DEPTHS = (2, 3, 4)      # union; every streamed kernel accepts these

STATS = {"microbench_calls": 0, "cache_hits": 0, "tuned": 0,
         "cache_invalid": 0}

_ENABLED: Optional[bool] = None           # None -> read $REPRO_AUTOTUNE
_MEM_CACHE: Dict[str, Dict[str, Any]] = {}  # abs cache path -> loaded file
_BENCH_OVERRIDE: Optional[Callable] = None  # tests inject a fake micro-bench


class TileDecision(NamedTuple):
    tiles: Optional[Dict[str, int]]   # None: no legal candidate fits
    provenance: str                   # "heuristic" | "tuned" | "none"


# ---------------------------------------------------------------------------
# Tuner state knobs
# ---------------------------------------------------------------------------

def enabled() -> bool:
    if _ENABLED is not None:
        return _ENABLED
    return os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0", "false")


def enable(on: Optional[bool] = True) -> None:
    """Force tuning on/off for this process; ``enable(None)`` re-reads the
    ``REPRO_AUTOTUNE`` env var."""
    global _ENABLED
    _ENABLED = on


def reset(*, memory_only: bool = False) -> None:
    """Drop the in-memory cache mirror (tests); optionally keep STATS."""
    _MEM_CACHE.clear()
    if not memory_only:
        for k in STATS:
            STATS[k] = 0


def set_benchmark_override(fn: Optional[Callable]) -> None:
    """Tests: replace the real micro-benchmark with ``fn(family, dims, tiles)
    -> us``. The microbench_calls counter still increments."""
    global _BENCH_OVERRIDE
    _BENCH_OVERRIDE = fn


def active_backend() -> str:
    import jax
    return jax.default_backend()


def active_hardware() -> Hardware:
    return hardware_for(active_backend())


def default_vmem_budget(hw: Optional[Hardware] = None) -> int:
    """Per-kernel VMEM working-set budget: ``$REPRO_VMEM_BUDGET`` if set, else
    ``KERNEL_VMEM_FRACTION`` of the active Hardware model's capacity."""
    env = os.environ.get("REPRO_VMEM_BUDGET")
    if env:
        return int(env)
    hw = hw if hw is not None else active_hardware()
    return int(hw.vmem_bytes * KERNEL_VMEM_FRACTION)


def cache_path(backend: Optional[str] = None) -> str:
    backend = backend or active_backend()
    root = os.environ.get("REPRO_AUTOTUNE_CACHE") or DEFAULT_CACHE_DIR
    return os.path.join(os.path.expanduser(root), f"{backend}.json")


# ---------------------------------------------------------------------------
# Working-set accounting — the single source of the VMEM fit formulas
# ---------------------------------------------------------------------------

def ws_matmul_tile(k_pad: int, tn: int, bytes_per_el: int) -> int:
    """Blocked grouped-GEMM step (cvmm_pallas / fused w2): one (TM, K) operand
    tile, one (K, tn) weight tile, one (TM, tn) f32 accumulator."""
    return TM * k_pad * bytes_per_el + k_pad * tn * bytes_per_el + TM * tn * 4


def ws_fused_w1(k_pad: int, tn: int, bytes_per_el: int, n_weights: int,
                n_out: int, n_buffers: int = 2) -> int:
    """Streamed gather-fused w1 step: ``n_buffers`` (TM, K) gather scratch
    slots plus weight/output tiles at 2x for Mosaic's pipeline
    double-buffering of blocked operands."""
    scratch = n_buffers * TM * k_pad * bytes_per_el
    return scratch + 2 * (n_weights * k_pad * tn * bytes_per_el
                          + n_out * TM * tn * max(bytes_per_el, 4))


def ws_streamed_dw(stream_w: int, tb: int, bytes_per_el: int,
                   n_buffers: int = 2) -> int:
    """Streamed dW step: gather scratch over the streamed width plus the
    blocked (TM, tb) operand tile and (W_stream, tb) f32 output at 2x."""
    scratch = n_buffers * TM * stream_w * bytes_per_el
    return scratch + 2 * (TM * tb * bytes_per_el + stream_w * tb * 4)


def ws_gather(k_pad: int, bytes_per_el: int, n_buffers: int = 2) -> int:
    """Streamed bare-gather step: scratch slots plus the blocked output tile
    at 2x for pipeline double-buffering."""
    return (n_buffers * TM * k_pad * bytes_per_el
            + 2 * TM * k_pad * bytes_per_el)


def _dividing_widths(n_pad: int) -> List[int]:
    """All multiples of LANE that divide ``n_pad``, largest first — the legal
    tile widths (kernels assert divisibility; Mosaic lanes demand the LANE
    multiple). This is the satellite fix for the old fixed ladder's
    divisibility miss: n_pad=640 yields (640, 128), not just 128."""
    return [t for t in range(n_pad, 0, -LANE) if n_pad % t == 0]


# ---------------------------------------------------------------------------
# Candidate enumeration + roofline cost per kernel family
# ---------------------------------------------------------------------------
# A family spec is (candidates, cost, bench, run_class):
#   candidates(dims, budget) -> ordered [tiles dict, ...]; element 0 is the
#       heuristic answer (largest width, shallowest pipeline).
#   cost(dims, tiles, hw)    -> estimated seconds for a reference pass of
#       M_REF_TILES row tiles (ranking only; absolute value is not claimed).
#   bench(dims, tiles)       -> measured us for the same reference pass.

def _cand_pick_tn(dims, budget):
    k_pad, b = dims["k_pad"], dims["b"]
    return [{"tm": TM, "tn": tn} for tn in _dividing_widths(dims["n_pad"])
            if ws_matmul_tile(k_pad, tn, b) <= budget]


def _cost_pick_tn(dims, tiles, hw):
    k_pad, n_pad, b = dims["k_pad"], dims["n_pad"], dims["b"]
    tn = tiles["tn"]
    m = M_REF_TILES
    steps = m * (n_pad // tn)
    bytes_moved = (m * k_pad * n_pad * b          # weight tile per grid step
                   + m * TM * k_pad * b           # operand tile per m pass
                   + m * TM * n_pad * b)          # output
    flops = 2 * m * TM * k_pad * n_pad
    return max(bytes_moved / hw.hbm_bw, flops / hw.peak_flops) \
        + steps * STEP_OVERHEAD_S


def _cost_decode_gemm(dims, tiles, hw):
    """Decode shape-class: ONE live row tile (a continuous-batching decode
    step routes at most a few hundred rows), so the pass is weight-stream
    bound — the full (K, N) weight panel moves through VMEM for a single
    (TM, K) operand tile and per-step overhead dominates the ranking."""
    k_pad, n_pad, b = dims["k_pad"], dims["n_pad"], dims["b"]
    tn = tiles["tn"]
    steps = n_pad // tn
    bytes_moved = (k_pad * n_pad * b      # the whole weight panel, once
                   + TM * k_pad * b       # one operand tile
                   + TM * n_pad * b)      # one output stripe
    flops = 2 * TM * k_pad * n_pad
    return max(bytes_moved / hw.hbm_bw, flops / hw.peak_flops) \
        + steps * STEP_OVERHEAD_S


def _cand_fused_w1(dims, budget):
    k_pad, b = dims["k_pad"], dims["b"]
    nw, no = dims["n_weights"], dims["n_out"]
    out = []
    for depth in FAMILY_DEPTHS["fused_w1"] if enabled() else (2,):
        out += [{"tm": TM, "tn": tn, "n_buffers": depth}
                for tn in _dividing_widths(dims["n_pad"])
                if ws_fused_w1(k_pad, tn, b, nw, no, depth) <= budget]
    # heuristic order: depth 2 first, widths descending within a depth
    out.sort(key=lambda t: (t["n_buffers"], -t["tn"]))
    return out


def _cost_fused_w1(dims, tiles, hw):
    k_pad, n_pad, b = dims["k_pad"], dims["n_pad"], dims["b"]
    nw, no = dims["n_weights"], dims["n_out"]
    m = M_REF_TILES
    steps = m * (n_pad // tiles["tn"])
    bytes_moved = (m * nw * k_pad * n_pad * b     # weight tiles, re-read per m
                   + m * TM * k_pad * b           # streamed gather rows
                   + no * m * TM * n_pad * b)     # outputs
    flops = 2 * m * TM * k_pad * n_pad * nw
    # deeper pipelines hide more DMA latency behind the MXU: model as a mild
    # discount on the per-step overhead (measurement decides the rest)
    overhead = steps * STEP_OVERHEAD_S * (2.0 / tiles.get("n_buffers", 2))
    return max(bytes_moved / hw.hbm_bw, flops / hw.peak_flops) + overhead


def _cand_streamed_dw(dims, budget):
    sw, b = dims["stream_w"], dims["b"]
    out = []
    for depth in FAMILY_DEPTHS["streamed_dw"] if enabled() else (2,):
        out += [{"tm": TM, "tb": tb, "n_buffers": depth}
                for tb in _dividing_widths(dims["block_w"])
                if ws_streamed_dw(sw, tb, b, depth) <= budget]
    out.sort(key=lambda t: (t["n_buffers"], -t["tb"]))
    return out


def _cost_streamed_dw(dims, tiles, hw):
    sw, bw, b = dims["stream_w"], dims["block_w"], dims["b"]
    tb = tiles["tb"]
    m = M_REF_TILES
    passes = bw // tb
    steps = passes * m
    # the gather stream RESTARTS on every outer pass: larger tb -> fewer
    # re-streams of the whole unsorted operand — the tb-dependent term
    bytes_moved = (passes * m * TM * sw * b       # streamed rows, per pass
                   + m * TM * bw * b              # blocked operand tiles
                   + passes * sw * tb * 4)        # f32 output blocks
    flops = 2 * m * TM * sw * bw
    overhead = steps * STEP_OVERHEAD_S * (2.0 / tiles.get("n_buffers", 2))
    return max(bytes_moved / hw.hbm_bw, flops / hw.peak_flops) + overhead


def _cand_gather(dims, budget):
    k_pad, b = dims["k_pad"], dims["b"]
    depths = FAMILY_DEPTHS["gather"] if enabled() else (2,)
    return [{"tm": TM, "n_buffers": d} for d in depths
            if ws_gather(k_pad, b, d) <= budget]


def _cost_gather(dims, tiles, hw):
    k_pad, b = dims["k_pad"], dims["b"]
    m = M_REF_TILES
    bytes_moved = 2 * m * TM * k_pad * b          # rows in, tile out
    overhead = m * STEP_OVERHEAD_S * (2.0 / tiles.get("n_buffers", 2))
    return bytes_moved / hw.hbm_bw + overhead


def _cost_gather_dedup(dims, tiles, hw):
    """Same streamed gather kernel at the dedup plan's SORTED routing: the
    sorted-unique row space packs ~TM/32-descriptor tiles (blocks of adjacent
    value indices) instead of the mixed plan's ~TM/2, so the per-step
    descriptor overhead — the term the pipeline depth amortizes — is ~1/4 of
    the mixed family's. Byte traffic is identical; the distinct cost shape is
    what makes this a separate cache shape-class."""
    k_pad, b = dims["k_pad"], dims["b"]
    m = M_REF_TILES
    bytes_moved = 2 * m * TM * k_pad * b
    overhead = m * (STEP_OVERHEAD_S / 4) * (2.0 / tiles.get("n_buffers", 2))
    return bytes_moved / hw.hbm_bw + overhead


# ---------------------------------------------------------------------------
# Micro-benchmarks (lazy kernel imports; only run when tuning is enabled)
# ---------------------------------------------------------------------------

def _time_us(fn) -> float:
    import jax
    jax.block_until_ready(fn())                   # compile outside the clock
    best = float("inf")
    for _ in range(BENCH_ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _bench_dtype(b: int):
    import jax.numpy as jnp
    return {1: jnp.int8, 2: jnp.bfloat16, 4: jnp.float32}[b]


def _interpret() -> bool:
    return active_backend() != "tpu"


def _mixed_plan(m_pad: int):
    """Reference gather routing at run_class "mixed": the first half of the
    slots are one contiguous run per tile (large DMA chunk classes), the
    second half stride-2 scattered rows (size-1 chunks) — both ends of the
    run-batched pipeline's chunk-size dispatch get exercised."""
    import jax.numpy as jnp
    import numpy as np
    from . import ops
    half = m_pad // 2
    src = np.empty((m_pad,), np.int32)
    src[:half] = np.arange(half)
    src[half:] = (half + 2 * np.arange(m_pad - half)) % m_pad
    row_src = jnp.asarray(src)
    run_start, _, run_off = ops._plan_runs(row_src, m_pad)
    return row_src, run_start, run_off


def _bench_pick_tn(dims, tiles) -> float:
    import jax
    import jax.numpy as jnp
    from . import cvmm
    dt = _bench_dtype(dims["b"])
    m_pad = M_REF_TILES * TM
    x = jnp.ones((m_pad, dims["k_pad"]), dt)
    te = jnp.zeros((M_REF_TILES,), jnp.int32)
    w = jnp.ones((1, dims["k_pad"], dims["n_pad"]), dt)
    f = jax.jit(functools.partial(cvmm.cvmm_pallas, interpret=_interpret(),
                                  tn=tiles["tn"]))
    return _time_us(lambda: f(x, te, w))


def _bench_decode_gemm(dims, tiles) -> float:
    import jax
    import jax.numpy as jnp
    from . import cvmm
    dt = _bench_dtype(dims["b"])
    x = jnp.ones((TM, dims["k_pad"]), dt)         # one row tile: decode-sized
    te = jnp.zeros((1,), jnp.int32)
    w = jnp.ones((1, dims["k_pad"], dims["n_pad"]), dt)
    f = jax.jit(functools.partial(cvmm.cvmm_pallas, interpret=_interpret(),
                                  tn=tiles["tn"]))
    return _time_us(lambda: f(x, te, w))


def _bench_fused_w1(dims, tiles) -> float:
    import jax
    import jax.numpy as jnp
    from . import cvmm
    dt = _bench_dtype(dims["b"])
    m_pad = M_REF_TILES * TM
    row_src, run_start, run_off = _mixed_plan(m_pad)
    te = jnp.zeros((M_REF_TILES,), jnp.int32)
    x = jnp.ones((m_pad, dims["k_pad"]), dt)
    w1 = jnp.ones((1, dims["k_pad"], dims["n_pad"]), dt)
    glu = dims["n_weights"] == 2
    f = jax.jit(functools.partial(
        cvmm.cvmm_fused_w1_pallas, act_name="relu",
        save_preact=dims["n_out"] > 1, interpret=_interpret(),
        tn=tiles["tn"], n_buffers=tiles["n_buffers"]))
    return _time_us(lambda: f(x, row_src, run_start, run_off, te, w1,
                              w1 if glu else None))


def _bench_streamed_dw(dims, tiles) -> float:
    import jax
    import jax.numpy as jnp
    from . import cvmm
    dt = _bench_dtype(dims["b"])
    m_pad = M_REF_TILES * TM
    row_src, run_start, run_off = _mixed_plan(m_pad)
    te = jnp.zeros((M_REF_TILES,), jnp.int32)
    x = jnp.ones((m_pad, dims["stream_w"]), dt)       # streamed, stays in HBM
    g = jnp.ones((m_pad, dims["block_w"]), dt)        # tile-aligned, blocked
    f = jax.jit(functools.partial(
        cvmm.cvmm_dw_streamed_pallas, n_experts=1, stream_x=True,
        interpret=_interpret(), tb=tiles["tb"], n_buffers=tiles["n_buffers"]))
    return _time_us(lambda: f(x, g, row_src, run_start, run_off, te))


def _bench_gather(dims, tiles) -> float:
    import jax
    import jax.numpy as jnp
    from . import cvmm
    dt = _bench_dtype(dims["b"])
    m_pad = M_REF_TILES * TM
    row_src, run_start, run_off = _mixed_plan(m_pad)
    x = jnp.ones((m_pad, dims["k_pad"]), dt)
    f = jax.jit(functools.partial(cvmm.cvmm_gather_rows_pallas,
                                  interpret=_interpret(),
                                  n_buffers=tiles["n_buffers"]))
    return _time_us(lambda: f(x, row_src, run_start, run_off))


def _sorted_plan(m_pad: int):
    """Reference gather routing at run_class "sorted": ascending row ids in
    32-row blocks separated by gaps — the dedup plan's characteristic layout
    (sorted-unique value indices: dense stretches of co-selected hot rows
    with cold-row gaps between them). Every tile packs into size-32 chunks,
    exercising the large-class end the mixed plan only half-covers. Sources
    span 2*m_pad rows so the gapped pattern stays in bounds."""
    import jax.numpy as jnp
    import numpy as np
    from . import ops
    j = np.arange(m_pad)
    src = (j // 32) * 64 + (j % 32)
    row_src = jnp.asarray(src.astype(np.int32))
    run_start, _, run_off = ops._plan_runs(row_src, 2 * m_pad)
    return row_src, run_start, run_off


def _bench_gather_dedup(dims, tiles) -> float:
    import jax
    import jax.numpy as jnp
    from . import cvmm
    dt = _bench_dtype(dims["b"])
    m_pad = M_REF_TILES * TM
    row_src, run_start, run_off = _sorted_plan(m_pad)
    x = jnp.ones((2 * m_pad, dims["k_pad"]), dt)
    f = jax.jit(functools.partial(cvmm.cvmm_gather_rows_pallas,
                                  interpret=_interpret(),
                                  n_buffers=tiles["n_buffers"]))
    return _time_us(lambda: f(x, row_src, run_start, run_off))


class _Family(NamedTuple):
    candidates: Callable
    cost: Callable
    bench: Callable
    run_class: str


_FAMILIES: Dict[str, _Family] = {
    "pick_tn": _Family(_cand_pick_tn, _cost_pick_tn, _bench_pick_tn, "dense"),
    # Same blocked-GEMM kernel + candidate set as "pick_tn", but costed and
    # measured at ONE row tile — the continuous-batching decode step's tiny-M
    # regime, where training-amortized tile choices stop being representative.
    # A separate shape-class keeps tuned decode winners from overwriting the
    # 24k-token training winners (and vice versa).
    "decode_gemm": _Family(_cand_pick_tn, _cost_decode_gemm,
                           _bench_decode_gemm, "decode"),
    "fused_w1": _Family(_cand_fused_w1, _cost_fused_w1, _bench_fused_w1,
                        "mixed"),
    "streamed_dw": _Family(_cand_streamed_dw, _cost_streamed_dw,
                           _bench_streamed_dw, "mixed"),
    "gather": _Family(_cand_gather, _cost_gather, _bench_gather, "mixed"),
    # Same kernel + candidate set as "gather", but measured/modeled at the
    # dedup plan's sorted-unique routing — a separate shape-class so tuned
    # winners for mixed vs sorted contiguity never overwrite each other.
    "gather_dedup": _Family(_cand_gather, _cost_gather_dedup,
                            _bench_gather_dedup, "sorted"),
}


def families() -> tuple:
    """Every kernel family the tuner can resolve (analysis sweeps these)."""
    return tuple(_FAMILIES)


def enumerate_candidates(family: str, dims: Dict[str, int], *,
                         budget: Optional[int] = None,
                         tuned: bool = True) -> List[Dict[str, int]]:
    """Every tile candidate ``decide(family, dims)`` could ever return — the
    exact list the tuner ranks, heuristic answer first.

    ``tuned=True`` enumerates the full tuning-enabled candidate space (all
    ``FAMILY_DEPTHS`` entries); ``tuned=False`` restricts to what the disabled
    tuner can emit. The VMEM-budget prover (repro.analysis.vmem) walks this
    with an independently derived working-set model: any candidate surviving
    here but busting the budget there is a tile-picker regression caught
    before a kernel ever launches."""
    budget = budget if budget is not None else default_vmem_budget()
    prev = _ENABLED
    enable(tuned)
    try:
        return _FAMILIES[family].candidates(dims, budget)
    finally:
        enable(prev)


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

def _key(family: str, dims: Dict[str, int]) -> str:
    return family + "|" + "|".join(f"{k}={dims[k]}" for k in sorted(dims))


def _fresh_file(backend: str, hw: Hardware) -> Dict[str, Any]:
    return {"schema": SCHEMA_VERSION, "backend": backend,
            "hardware": hw.name, "entries": {}}


def _valid_file(data) -> bool:
    return (isinstance(data, dict) and data.get("schema") == SCHEMA_VERSION
            and isinstance(data.get("entries"), dict))


def _read_disk(path: str) -> Optional[Dict[str, Any]]:
    """Load + validate the cache file; any corruption or schema drift is
    reported as a miss (STATS["cache_invalid"]) and the file gets rebuilt by
    the next store — never an exception."""
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return None
    except (OSError, ValueError):
        STATS["cache_invalid"] += 1
        return None
    if not _valid_file(data):
        STATS["cache_invalid"] += 1
        return None
    return data


def _load_cache(path: str) -> Dict[str, Any]:
    if path not in _MEM_CACHE:
        _MEM_CACHE[path] = _read_disk(path) \
            or _fresh_file(active_backend(), active_hardware())
    return _MEM_CACHE[path]


def _store(path: str, key: str, entry: Dict[str, Any]) -> None:
    """Merge-with-disk read-modify-write published via atomic rename:
    concurrent writers each land their own entries; readers never observe a
    torn file."""
    data = _read_disk(path) or _fresh_file(active_backend(),
                                           active_hardware())
    data["entries"][key] = entry
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tune-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(data, fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _MEM_CACHE[path] = data


def _entry_tiles(entry, candidates) -> Optional[Dict[str, int]]:
    """A cached entry is honored only if its tiles are STILL a legal candidate
    under the current budget (tests shrink budgets; hardware models change)."""
    if not isinstance(entry, dict):
        return None
    tiles = entry.get("tiles")
    if isinstance(tiles, dict) and tiles in candidates:
        return dict(tiles)
    return None


def _measure(family: str, dims: Dict[str, int], tiles: Dict[str, int]) -> float:
    STATS["microbench_calls"] += 1
    fn = _BENCH_OVERRIDE or (lambda f, d, t: _FAMILIES[f].bench(d, t))
    return float(fn(family, dims, tiles))


# ---------------------------------------------------------------------------
# The query
# ---------------------------------------------------------------------------

def decide(family: str, dims: Dict[str, int], *,
           budget: Optional[int] = None) -> TileDecision:
    """Resolve one kernel family's tiles at one shape class.

    Disabled tuner: first legal candidate (the heuristic), zero cost.
    Enabled: cached winner if still legal, else roofline-prune + micro-bench
    the top-k and persist the winner."""
    budget = budget if budget is not None else default_vmem_budget()
    spec = _FAMILIES[family]
    cands = spec.candidates(dims, budget)
    if not cands:
        return TileDecision(None, "none")
    if not enabled():
        return TileDecision(dict(cands[0]), "heuristic")

    path = cache_path()
    key = _key(family, dims)
    cached = _entry_tiles(_load_cache(path)["entries"].get(key), cands)
    if cached is not None:
        STATS["cache_hits"] += 1
        return TileDecision(cached, "tuned")

    hw = active_hardware()
    ranked = sorted(range(len(cands)),
                    key=lambda i: (spec.cost(dims, cands[i], hw), i))
    survivors = [cands[i] for i in ranked[:TUNE_TOP_K]]
    if len(survivors) == 1:
        best, best_us = survivors[0], None
    else:
        best, best_us = survivors[0], float("inf")
        for t in survivors:                     # stable: first strict win
            us = _measure(family, dims, t)
            if us < best_us:
                best, best_us = t, us
    _store(path, key, {
        "tiles": best, "provenance": "tuned", "us": best_us,
        "estimate_s": spec.cost(dims, best, hw), "run_class": spec.run_class})
    STATS["tuned"] += 1
    return TileDecision(dict(best), "tuned")


# Thin per-family views used by kernels/cvmm.py (budget threaded from the
# caller so ``cvmm.VMEM_BUDGET`` monkeypatches shrink everything at once).

def pick_tn(k_pad: int, n_pad: int, bytes_per_el: int, *,
            budget: Optional[int] = None) -> Optional[int]:
    d = decide("pick_tn", {"k_pad": k_pad, "n_pad": n_pad, "b": bytes_per_el},
               budget=budget)
    return None if d.tiles is None else d.tiles["tn"]


def decode_gemm_tiles(k_pad: int, n_pad: int, bytes_per_el: int, *,
                      budget: Optional[int] = None) -> TileDecision:
    """Tile width for the decode-shaped grouped GEMM (ops.DecodePlan): same
    kernel and candidates as ``pick_tn``, separate shape-class so decode
    winners are tuned at tiny-M instead of inheriting training tiles."""
    return decide("decode_gemm", {"k_pad": k_pad, "n_pad": n_pad,
                                  "b": bytes_per_el}, budget=budget)


def fused_w1_tiles(k_pad: int, n_pad: int, bytes_per_el: int, n_weights: int,
                   n_out: int, *, budget: Optional[int] = None) -> TileDecision:
    return decide("fused_w1", {"k_pad": k_pad, "n_pad": n_pad,
                               "b": bytes_per_el, "n_weights": n_weights,
                               "n_out": n_out}, budget=budget)


def streamed_dw_tiles(stream_w: int, block_w: int, bytes_per_el: int, *,
                      budget: Optional[int] = None) -> TileDecision:
    return decide("streamed_dw", {"stream_w": stream_w, "block_w": block_w,
                                  "b": bytes_per_el}, budget=budget)


def gather_tiles(k_pad: int, bytes_per_el: int, *,
                 budget: Optional[int] = None) -> TileDecision:
    return decide("gather", {"k_pad": k_pad, "b": bytes_per_el},
                  budget=budget)


def dedup_gather_tiles(k_pad: int, bytes_per_el: int, *,
                       budget: Optional[int] = None) -> TileDecision:
    """Pipeline depth for the dedup/sorted gather (ops.DedupGatherPlan):
    same kernel and candidates as ``gather_tiles``, separate shape-class —
    the sorted routing's larger chunks shift where extra depth pays."""
    return decide("gather_dedup", {"k_pad": k_pad, "b": bytes_per_el},
                  budget=budget)


def gather_fits(k_pad: int, bytes_per_el: int, n_buffers: int = 2, *,
                budget: Optional[int] = None) -> bool:
    budget = budget if budget is not None else default_vmem_budget()
    return ws_gather(k_pad, bytes_per_el, n_buffers) <= budget
