"""jit-ready CVMM wrapper: layout plan + backend dispatch + custom_vjp.

Backends
--------
"pallas"        The TPU kernels (cvmm.py), unfused: rows are gathered/sorted at
                the XLA level, each grouped GEMM is one pallas_call. On CPU the
                kernels run in interpret mode — used by the tests.
"pallas_fused"  The fused pipeline: one ``CvmmPlan`` computed per MoE call, a
                streamed gather-fused w1 kernel (activations stay in HBM and
                double-buffer through VMEM row tile by row tile — any token
                count) with activation/GLU epilogue and a w2 kernel with the
                gate multiply fused in. The plan is threaded through forward
                and backward via custom_vjp residuals — no layout recompute,
                no re-pad in backward, and the backward's gathers reuse the
                same streamed row-DMA pipeline. Exposed at the MoE-MLP
                granularity via ``moe_mlp_fused``; for the bare ``cvmm`` API it
                degrades to the planned unfused path (a single GEMM has no
                epilogue to fuse).
"ragged"        jax.lax.ragged_dot — XLA's grouped matmul; differentiable; the
                default on CPU and a correctness cross-check on TPU.
"ref"           Pure-jnp one-hot oracle (kernels/ref.py), O(N*E) — tests only.

The public ``cvmm(x, group_sizes, w)`` takes rows already *sorted by expert*
(group_sizes sums to rows) and returns x[i] @ w[expert(i)].

Layout plan
-----------
``CvmmPlan`` (see kernels/cvmm.py for the field contract) is computed ONCE per
MoE call by ``make_moe_plan`` and reused by every kernel launch of that call,
forward and backward. ``_tile_layout`` is the single source of the tile-aligned
layout math; nothing recomputes it downstream of a plan.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes

from ..common import act_fn, round_up
from . import ref as refk
from .cvmm import (FUSIBLE_ACTIVATIONS, LANE, TM, cvmm_dw_pallas,
                   cvmm_fused_w1_pallas, cvmm_fused_w2_pallas,
                   cvmm_gather_rows_pallas, cvmm_pallas, fused_w1_tn)

_FORCED_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    global _FORCED_IMPL
    _FORCED_IMPL = impl


def default_impl() -> str:
    if _FORCED_IMPL:
        return _FORCED_IMPL
    return "pallas_fused" if jax.default_backend() == "tpu" else "ragged"


def _impl_interpret(impl: str) -> bool:
    return impl.endswith("_interpret") or jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Tile-aligned layout plan (megablocks-style)
# ---------------------------------------------------------------------------

class CvmmPlan(NamedTuple):
    """One-per-MoE-call layout metadata shared by all kernel launches.

    Field contract documented in kernels/cvmm.py. ``m_pad`` is static:
    ``tile_expert.shape[0] * TM``. All int fields get float0 cotangents;
    ``gate_tiles`` is the one differentiable leaf (grads flow back to routing).
    """
    perm: jax.Array          # (N*K,) argsort of flat expert ids (stable)
    group_sizes: jax.Array   # (E,) rows per expert
    new_pos: jax.Array       # (N*K,) tile-aligned slot of sorted row i
    row_src: jax.Array       # (M_pad,) source token row; sentinel N on slack
    tile_expert: jax.Array   # (M_pad//TM,) row-tile -> expert id
    gate_tiles: jax.Array    # (M_pad//TM, TM) float32 gate per slot, 0 on slack

    @property
    def m_pad(self) -> int:
        return self.tile_expert.shape[0] * TM


def _tile_layout(group_sizes: jax.Array, m: int, e: int):
    """Map sorted rows to a layout where each expert's range is TM-aligned.

    Returns (new_pos (m,), tile_expert (m_pad//TM,), m_pad). m_pad is a static
    upper bound m + e*TM; slack tiles are all-zero and clamped to the last expert.
    """
    gs = group_sizes.astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(gs)])[:-1]
    ps = ((gs + TM - 1) // TM) * TM                       # padded group sizes
    offs_p = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(ps)])[:-1]
    rows = jnp.arange(m, dtype=jnp.int32)
    re = refk.row_experts(gs, m).astype(jnp.int32)
    new_pos = offs_p[re] + (rows - offs[re])
    m_pad = round_up(m, TM) + e * TM
    n_tiles = m_pad // TM
    ends_p = jnp.cumsum(ps)
    tile_expert = jnp.searchsorted(ends_p, jnp.arange(n_tiles, dtype=jnp.int32) * TM,
                                   side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, e - 1)         # clamp slack tiles
    return new_pos, tile_expert, m_pad


def make_moe_plan(idx: jax.Array, gates: jax.Array, n_tokens: int,
                  n_experts: int) -> CvmmPlan:
    """Build the CvmmPlan for one MoE call from the routing selection.

    idx (N, K) int expert ids, gates (N, K) gate values. Differentiable in
    ``gates`` (the scatter into ``gate_tiles`` is transparent to autodiff)."""
    k = idx.shape[-1]
    e_flat = idx.reshape(-1).astype(jnp.int32)
    g_flat = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n_tokens, dtype=jnp.int32), k)
    perm = jnp.argsort(e_flat, stable=True)
    group_sizes = jnp.bincount(e_flat, length=n_experts).astype(jnp.int32)
    new_pos, tile_expert, m_pad = _tile_layout(group_sizes, e_flat.shape[0],
                                               n_experts)
    row_src = jnp.full((m_pad,), n_tokens, jnp.int32).at[new_pos].set(tok[perm])
    gate_pad = jnp.zeros((m_pad,), jnp.float32).at[new_pos].set(
        g_flat[perm].astype(jnp.float32))
    return CvmmPlan(perm=perm, group_sizes=group_sizes, new_pos=new_pos,
                    row_src=row_src, tile_expert=tile_expert,
                    gate_tiles=gate_pad.reshape(m_pad // TM, TM))


def _float0(a: jax.Array):
    return np.zeros(a.shape, dtypes.float0)


def _pad_lane(a: jax.Array, axis: int) -> jax.Array:
    size = a.shape[axis]
    pad = round_up(size, LANE) - size
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pad_w(w: jax.Array) -> jax.Array:
    return _pad_lane(_pad_lane(w, 1), 2)


def _mask_empty(dw: jax.Array, group_sizes: jax.Array) -> jax.Array:
    # Blocks of experts with zero rows are never visited by the dW kernel
    # (their padded group has no tiles) and stay uninitialized.
    return jnp.where((group_sizes > 0)[:, None, None], dw, 0.0)


# ---------------------------------------------------------------------------
# Unfused pallas path with plan-threaded custom_vjp
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _cvmm_planned(x, new_pos, tile_expert, group_sizes, w, interpret):
    return _planned_fwd(x, new_pos, tile_expert, group_sizes, w, interpret)[0]


def _planned_fwd(x, new_pos, tile_expert, group_sizes, w, interpret):
    n = w.shape[2]
    m_pad = tile_expert.shape[0] * TM
    x_pad = jnp.zeros((m_pad, round_up(x.shape[1], LANE)), x.dtype)
    x_pad = x_pad.at[new_pos].set(_pad_lane(x, 1))
    out_pad = cvmm_pallas(x_pad, tile_expert, _pad_w(w), interpret=interpret)
    # Residuals carry the plan arrays AND the padded activations: backward does
    # zero layout recompute and pads only the incoming cotangent.
    return out_pad[new_pos, :n], (x_pad, new_pos, tile_expert, group_sizes, w)


def _planned_bwd(interpret, res, g):
    x_pad, new_pos, tile_expert, group_sizes, w = res
    e, k, n = w.shape
    m_pad = x_pad.shape[0]
    g_pad = jnp.zeros((m_pad, round_up(n, LANE)), g.dtype)
    g_pad = g_pad.at[new_pos].set(_pad_lane(g, 1))
    w_pad = _pad_w(w)
    dx_pad = cvmm_pallas(g_pad, tile_expert, jnp.swapaxes(w_pad, 1, 2),
                         interpret=interpret)
    dx = dx_pad[new_pos, :k].astype(x_pad.dtype)
    dw = cvmm_dw_pallas(x_pad, tile_expert, g_pad, e, interpret=interpret)
    dw = _mask_empty(dw, group_sizes)[:, :k, :n].astype(w.dtype)
    return (dx, _float0(new_pos), _float0(tile_expert), _float0(group_sizes),
            dw)


_cvmm_planned.defvjp(_planned_fwd, _planned_bwd)


def cvmm_planned(x: jax.Array, plan: CvmmPlan, w: jax.Array,
                 *, interpret: bool) -> jax.Array:
    """Grouped matmul on *sorted* rows reusing a precomputed plan (no layout
    derivation inside — three calls in an MoE layer share one plan)."""
    return _cvmm_planned(x, plan.new_pos, plan.tile_expert, plan.group_sizes,
                         w.astype(x.dtype), interpret)


# ---------------------------------------------------------------------------
# Fused MoE-MLP pipeline (gather -> grouped GEMM -> epilogue)
# ---------------------------------------------------------------------------

def fused_supported(n_tokens: int, d_model: int, expert_size: int,
                    activation: str, dtype=jnp.float32,
                    glu: bool = False) -> bool:
    """Gate for the fused pipeline: TILE-level residency only.

    The streamed w1 kernel keeps the unsorted activations in HBM and
    double-buffers (TM, K) row tiles through VMEM, so the token count no
    longer appears in the residency check at all (``n_tokens`` is kept in the
    signature for callers/telemetry but cannot flip the answer). Callers fall
    back to the unfused path only when the activation is not tile-local or the
    per-step tile working set itself cannot fit at any tile size (huge
    d_model). Sized for the worst case (training: save_preact outputs)."""
    del n_tokens  # streamed: any row count is supported
    if activation not in FUSIBLE_ACTIVATIONS:
        return False
    n_weights = 2 if glu else 1
    return fused_w1_tn(round_up(d_model, LANE), round_up(expert_size, LANE),
                       jnp.dtype(dtype).itemsize, n_weights,
                       n_out=1 + n_weights) is not None


def _fused_fwd_impl(static, xf, plan, w1, w1g, w2, save_preact=False):
    act_name, interpret = static
    n, d = xf.shape
    # Lane-pad the feature dim only: the streamed kernel gathers rows straight
    # out of HBM, so no row-count padding is needed (sentinel row_src == n).
    xe = _pad_lane(xf, 1)
    w1_out = cvmm_fused_w1_pallas(
        xe, plan.row_src, plan.tile_expert, _pad_w(w1),
        _pad_w(w1g) if w1g is not None else None,
        act_name=act_name, save_preact=save_preact, interpret=interpret)
    u_pad = w1_out[0] if save_preact else w1_out
    y_pad = cvmm_fused_w2_pallas(u_pad, plan.tile_expert, _pad_w(w2),
                                 plan.gate_tiles, interpret=interpret)
    # row_src slack slots hold the sentinel n — out of bounds, dropped here.
    y = jnp.zeros((n, d), y_pad.dtype).at[plan.row_src].add(
        y_pad[:, :d], mode="drop")
    return y, xe, w1_out


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_mlp_fused(static, xf, plan, w1, w1g, w2):
    return _fused_fwd_impl(static, xf, plan, w1, w1g, w2)[0]


def _fused_fwd(static, xf, plan, w1, w1g, w2):
    # Under differentiation the w1 kernel also emits the pre-activations in the
    # same grid pass (one extra HBM write each) so backward runs zero recompute
    # GEMMs; the inference/primal path keeps the lean single-output kernel.
    y, xe, w1_out = _fused_fwd_impl(static, xf, plan, w1, w1g, w2,
                                    save_preact=True)
    preact = w1_out[1:]                                   # (h,) or (h, hg)
    return y, (xe, plan, w1, w1g, w2, preact, xf.shape)


def _fused_bwd(static, res, dy):
    act_name, interpret = static
    xe, plan, w1, w1g, w2, preact, (n, d) = res
    act = act_fn(act_name)
    e, _, gsz = w1.shape
    w1p, w2p = _pad_w(w1), _pad_w(w2)
    w1gp = _pad_w(w1g) if w1g is not None else None
    m_pad = plan.m_pad
    gate = plan.gate_tiles.reshape(m_pad)[:, None]        # (M_pad, 1) f32

    # The single layout materialization of the backward pass: cotangent and
    # activations into the tile-aligned layout via the SAME streamed
    # double-buffered row-DMA plan as forward (sentinel rows -> 0); the
    # unsorted arrays stay in HBM here too, no whole-array residency.
    dy_pad = cvmm_gather_rows_pallas(_pad_lane(dy, 1), plan.row_src,
                                     interpret=interpret)
    x_pad = cvmm_gather_rows_pallas(xe, plan.row_src, interpret=interpret)

    t0 = cvmm_pallas(dy_pad, plan.tile_expert, jnp.swapaxes(w2p, 1, 2),
                     interpret=interpret)                 # dy @ w2^T, no gate
    if w1g is not None:
        h, hg = preact
        u, eltwise_vjp = jax.vjp(lambda a, b: act(a) * b, h, hg)
    else:
        (h,) = preact
        u, eltwise_vjp = jax.vjp(act, h)

    # d/dgate[r] = dy[r] . (u[r] @ w2[e]) == (dy[r] @ w2[e]^T) . u[r] = t0 . u
    dgate = jnp.sum(t0.astype(jnp.float32) * u.astype(jnp.float32), axis=1)
    du = (t0.astype(jnp.float32) * gate).astype(u.dtype)
    if w1g is not None:
        dh, dhg = eltwise_vjp(du)
    else:
        (dh,) = eltwise_vjp(du)

    dyg_pad = (dy_pad.astype(jnp.float32) * gate).astype(dy_pad.dtype)
    dw2 = _mask_empty(
        cvmm_dw_pallas(u, plan.tile_expert, dyg_pad, e, interpret=interpret),
        plan.group_sizes)[:, :gsz, :d].astype(w2.dtype)
    dw1 = _mask_empty(
        cvmm_dw_pallas(x_pad, plan.tile_expert, dh, e, interpret=interpret),
        plan.group_sizes)[:, :d, :gsz].astype(w1.dtype)
    dx_pad = cvmm_pallas(dh, plan.tile_expert, jnp.swapaxes(w1p, 1, 2),
                         interpret=interpret)
    if w1g is not None:
        dw1g = _mask_empty(
            cvmm_dw_pallas(x_pad, plan.tile_expert, dhg, e,
                           interpret=interpret),
            plan.group_sizes)[:, :d, :gsz].astype(w1g.dtype)
        dx_pad = dx_pad + cvmm_pallas(dhg, plan.tile_expert,
                                      jnp.swapaxes(w1gp, 1, 2),
                                      interpret=interpret)
    else:
        dw1g = None

    dxf = jnp.zeros((n, xe.shape[1]), dx_pad.dtype).at[plan.row_src].add(
        dx_pad, mode="drop")[:, :d].astype(xe.dtype)
    dplan = CvmmPlan(
        perm=_float0(plan.perm), group_sizes=_float0(plan.group_sizes),
        new_pos=_float0(plan.new_pos), row_src=_float0(plan.row_src),
        tile_expert=_float0(plan.tile_expert),
        gate_tiles=dgate.reshape(plan.gate_tiles.shape))
    return dxf, dplan, dw1, dw1g, dw2


_moe_mlp_fused.defvjp(_fused_fwd, _fused_bwd)


def moe_mlp_fused(xf: jax.Array, plan: CvmmPlan, w1: jax.Array, w2: jax.Array,
                  w1g: Optional[jax.Array] = None, *, activation: str = "relu",
                  interpret: Optional[bool] = None) -> jax.Array:
    """Fused dropless expert MLP: y[t] = gate * (act(x @ w1[e]) [* x @ w1g[e]]) @ w2[e].

    xf (N, d) UNSORTED activations; the gather, activation/GLU and gate multiply
    all run inside the two kernel launches (see kernels/cvmm.py). Returns the
    per-(token, expert) outputs already scatter-added back to (N, d)."""
    if activation not in FUSIBLE_ACTIVATIONS:
        raise ValueError(f"activation {activation!r} is not tile-local; "
                         f"fusible: {FUSIBLE_ACTIVATIONS}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dt = xf.dtype
    return _moe_mlp_fused((activation, interpret), xf, plan, w1.astype(dt),
                          None if w1g is None else w1g.astype(dt),
                          w2.astype(dt))


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def cvmm(x: jax.Array, group_sizes: jax.Array, w: jax.Array,
         impl: Optional[str] = None) -> jax.Array:
    """Grouped matmul: rows of x (sorted by expert, sizes in group_sizes) times
    w (E, K, N). Returns (rows, N)."""
    impl = impl or default_impl()
    if impl == "ragged":
        return jax.lax.ragged_dot(x, w.astype(x.dtype),
                                  group_sizes.astype(jnp.int32))
    if impl == "ref":
        return refk.cvmm_ref(x, group_sizes, w)
    if impl in ("pallas", "pallas_interpret", "pallas_fused",
                "pallas_fused_interpret"):
        new_pos, tile_expert, _ = _tile_layout(group_sizes, x.shape[0],
                                               w.shape[0])
        return _cvmm_planned(x, new_pos, tile_expert,
                             group_sizes.astype(jnp.int32), w.astype(x.dtype),
                             _impl_interpret(impl))
    raise ValueError(f"unknown cvmm impl {impl}")
