"""jit-ready CVMM wrapper: layout plan + backend dispatch + custom_vjp.

Backends
--------
"pallas"        The TPU kernels (cvmm.py), unfused: rows are gathered/sorted at
                the XLA level, each grouped GEMM is one pallas_call. On CPU the
                kernels run in interpret mode — used by the tests.
"pallas_fused"  The fused pipeline: one ``CvmmPlan`` computed per MoE call, a
                streamed gather-fused w1 kernel (activations stay in HBM and
                double-buffer through VMEM row tile by row tile — any token
                count) with activation/GLU epilogue and a w2 kernel with the
                gate multiply fused in. The plan is threaded through forward
                and backward via custom_vjp residuals — no layout recompute,
                no re-pad in backward, and the backward is gather-free at the
                HBM level: dW/dX stream their unsorted operands through the
                same run-batched row-DMA pipeline instead of materializing
                tile-aligned copies. Exposed at the MoE-MLP granularity via
                ``moe_mlp_fused``; for the bare ``cvmm`` API it degrades to
                the planned unfused path (a single GEMM has no epilogue to
                fuse).
"ragged"        jax.lax.ragged_dot — XLA's grouped matmul; differentiable; the
                default on CPU and a correctness cross-check on TPU.
"ref"           Pure-jnp one-hot oracle (kernels/ref.py), O(N*E) — tests only.

The public ``cvmm(x, group_sizes, w)`` takes rows already *sorted by expert*
(group_sizes sums to rows) and returns x[i] @ w[expert(i)].

Layout plans
------------
``CvmmPlan`` (see kernels/cvmm.py for the field contract) is computed ONCE per
MoE call by ``make_moe_plan`` and reused by every kernel launch of that call,
forward and backward. ``_tile_layout`` is the single source of the tile-aligned
layout math; nothing recomputes it downstream of a plan.

``GatherPlan`` (``make_gather_plan`` + ``gathered_weighted_sum``) is the
expert_size-1 degenerate for the framework's weighted value aggregation —
PKM values, top-K W2 rows (core/dispatch.weighted_value_sum): no grouped
GEMM, only the run-batched streamed row-DMA gather with a fused per-row
weight epilogue and the scatter back to tokens. Shares ``_plan_runs`` and
the custom_vjp plan-threading discipline with the MoE pipeline.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes

from ..common import act_fn, round_up
from . import autotune
from . import cvmm as cvmm_mod
from . import ref as refk
from .cvmm import (FUSIBLE_ACTIVATIONS, LANE, TM, _RUN_SIZES,
                   cvmm_dw_pallas, cvmm_dw_streamed_pallas,
                   cvmm_fused_w1_pallas, cvmm_fused_w2_pallas,
                   cvmm_gather_rows_pallas, cvmm_pallas,
                   gather_tile_fits)

_FORCED_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    global _FORCED_IMPL
    _FORCED_IMPL = impl


def default_impl() -> str:
    if _FORCED_IMPL:
        return _FORCED_IMPL
    return "pallas_fused" if jax.default_backend() == "tpu" else "ragged"


def _impl_interpret(impl: str) -> bool:
    return impl.endswith("_interpret") or jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Tile-aligned layout plan (megablocks-style)
# ---------------------------------------------------------------------------

class CvmmPlan(NamedTuple):
    """One-per-MoE-call layout metadata shared by all kernel launches.

    Field contract documented in kernels/cvmm.py. ``m_pad`` is static:
    ``tile_expert.shape[0] * TM``. All int fields get float0 cotangents;
    ``gate_tiles`` is the one differentiable leaf (grads flow back to routing).
    """
    perm: jax.Array          # (N*K,) argsort of flat expert ids (stable)
    group_sizes: jax.Array   # (E,) rows per expert
    new_pos: jax.Array       # (N*K,) tile-aligned slot of sorted row i
    row_src: jax.Array       # (M_pad,) source token row; sentinel N on slack
    run_start: jax.Array     # (M_pad,) per-tile DMA chunk table (compacted):
    run_len: jax.Array       #   entry j of tile t (flat t*TM+j) copies
                             #   run_len[j] consecutive rows starting at
                             #   row_src[t*TM + run_start[j]] into tile slots
                             #   [run_start[j], +run_len[j]); 0 = unused.
                             #   Lengths are static power-of-two classes
                             #   (see _plan_runs / cvmm._RUN_SIZES).
    run_off: jax.Array       # (M_pad//TM * 9,) per-tile size-class boundaries
                             #   into that table: class ci's chunks sit at
                             #   entries [run_off[t*9+ci], run_off[t*9+ci+1])
                             #   — lets kernels loop per static class with no
                             #   per-entry size dispatch.
    tile_expert: jax.Array   # (M_pad//TM,) row-tile -> expert id
    gate_tiles: jax.Array    # (M_pad//TM, TM) float32 gate per slot, 0 on slack

    @property
    def m_pad(self) -> int:
        return self.tile_expert.shape[0] * TM


def _tile_layout(group_sizes: jax.Array, m: int, e: int):
    """Map sorted rows to a layout where each expert's range is TM-aligned.

    Returns (new_pos (m,), tile_expert (m_pad//TM,), m_pad). m_pad is a static
    upper bound m + e*TM; slack tiles are all-zero and clamped to the last expert.
    """
    gs = group_sizes.astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(gs)])[:-1]
    ps = ((gs + TM - 1) // TM) * TM                       # padded group sizes
    offs_p = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(ps)])[:-1]
    rows = jnp.arange(m, dtype=jnp.int32)
    re = refk.row_experts(gs, m).astype(jnp.int32)
    new_pos = offs_p[re] + (rows - offs[re])
    m_pad = round_up(m, TM) + e * TM
    n_tiles = m_pad // TM
    ends_p = jnp.cumsum(ps)
    tile_expert = jnp.searchsorted(ends_p, jnp.arange(n_tiles, dtype=jnp.int32) * TM,
                                   side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, e - 1)         # clamp slack tiles
    return new_pos, tile_expert, m_pad


def _plan_runs(row_src: jax.Array, n_rows: int):
    """Batch each tile's maximal contiguous ``row_src`` runs into DMA chunks.

    Returns (run_start, run_len, run_off). run_start/run_len are (M_pad,)
    int32: entry j of tile t (flat index t*TM + j) describes one HBM->VMEM
    copy of ``run_len[t*TM+j]`` consecutive source rows starting at
    ``row_src[t*TM + run_start[t*TM+j]]`` into the tile's slot range
    [run_start, run_start + run_len). DMA copy shapes must be static, so each
    maximal run is greedily decomposed into power-of-two chunks (the kernels
    predicate on ``cvmm._RUN_SIZES``): a fully contiguous tile is ONE
    descriptor, an isolated row is one size-1 descriptor — never more chunks
    than the old one-DMA-per-row scheme. ``run_len == 0`` marks unused
    entries; slack slots (sentinel ``row_src``) belong to no chunk and keep
    the kernels' zero fill.

    Each tile's chunk entries are grouped by size class (largest first, source
    order preserved within a class, unused entries last), and ``run_off``
    ((M_pad//TM)*(len(_RUN_SIZES)+1),) int32 carries the per-tile class
    boundaries: class ci's chunks occupy entries [run_off[t*C+ci],
    run_off[t*C+ci+1]) with C = len(_RUN_SIZES)+1. The kernels therefore run
    one dynamic-bound loop per STATIC size class — total iterations == #chunks
    — instead of dispatching on run_len per entry (run_len itself is kept in
    the plan for tests/telemetry; the kernels never read it)."""
    src = row_src.reshape(-1, TM).astype(jnp.int32)
    n_tiles = src.shape[0]
    valid = src < n_rows
    slots = jnp.arange(TM, dtype=jnp.int32)[None, :]
    prev_valid = jnp.pad(valid[:, :-1], ((0, 0), (1, 0)))
    prev_src = jnp.pad(src[:, :-1], ((0, 0), (1, 0)))
    contig = valid & prev_valid & (src == prev_src + 1)
    is_start = valid & ~contig
    is_end = valid & jnp.pad(~contig[:, 1:], ((0, 0), (0, 1)),
                             constant_values=True)
    start_pos = jax.lax.cummax(jnp.where(is_start, slots, -1), axis=1)
    end_pos = jax.lax.cummin(jnp.where(is_end, slots, TM), axis=1,
                             reverse=True)
    length = jnp.where(valid, end_pos - start_pos + 1, 0)
    off = slots - start_pos
    # Greedy power-of-two decomposition: a run of length L gets a chunk of
    # size 2^b at in-run offset (L >> (b+1)) << (b+1) for each set bit b.
    # cclass = index into the descending cvmm._RUN_SIZES (0 = size TM);
    # non-chunk slots get the sentinel class nc so argsort pushes them last.
    nc = len(_RUN_SIZES)
    csize = jnp.zeros_like(src)
    cclass = jnp.full_like(src, nc)
    for b in range(TM.bit_length()):
        chunk_off = (length >> (b + 1)) << (b + 1)
        sel = valid & ((length & (1 << b)) > 0) & (off == chunk_off)
        csize = jnp.where(sel, 1 << b, csize)
        cclass = jnp.where(sel, nc - 1 - b, cclass)
    order = jnp.argsort(cclass, axis=1, stable=True).astype(jnp.int32)
    run_len = jnp.take_along_axis(csize, order, axis=1)
    counts = jnp.sum(cclass[:, :, None] == jnp.arange(nc)[None, None, :],
                     axis=1)
    run_off = jnp.concatenate(
        [jnp.zeros((n_tiles, 1), jnp.int32),
         jnp.cumsum(counts, axis=1).astype(jnp.int32)], axis=1)
    return order.reshape(-1), run_len.reshape(-1), run_off.reshape(-1)


def make_moe_plan(idx: jax.Array, gates: jax.Array, n_tokens: int,
                  n_experts: int) -> CvmmPlan:
    """Build the CvmmPlan for one MoE call from the routing selection.

    idx (N, K) int expert ids, gates (N, K) gate values. Differentiable in
    ``gates`` (the scatter into ``gate_tiles`` is transparent to autodiff)."""
    k = idx.shape[-1]
    e_flat = idx.reshape(-1).astype(jnp.int32)
    g_flat = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n_tokens, dtype=jnp.int32), k)
    perm = jnp.argsort(e_flat, stable=True)
    group_sizes = jnp.bincount(e_flat, length=n_experts).astype(jnp.int32)
    new_pos, tile_expert, m_pad = _tile_layout(group_sizes, e_flat.shape[0],
                                               n_experts)
    row_src = jnp.full((m_pad,), n_tokens, jnp.int32).at[new_pos].set(tok[perm])
    run_start, run_len, run_off = _plan_runs(row_src, n_tokens)
    gate_pad = jnp.zeros((m_pad,), jnp.float32).at[new_pos].set(
        g_flat[perm].astype(jnp.float32))
    return CvmmPlan(perm=perm, group_sizes=group_sizes, new_pos=new_pos,
                    row_src=row_src, run_start=run_start, run_len=run_len,
                    run_off=run_off, tile_expert=tile_expert,
                    gate_tiles=gate_pad.reshape(m_pad // TM, TM))


def plan_dma_stats(plan, n_rows: int, *, verify: bool = False) -> dict:
    """Telemetry: one plan's gather-DMA descriptor counts — run-batched chunks
    (what each streamed kernel pass issues, ``run_len > 0`` entries) vs the
    retired one-copy-per-row scheme, plus a per-size-class chunk histogram
    (``chunk_hist``: descriptor count per ``cvmm._RUN_SIZES`` class — shows
    whether packing ever reaches the large classes, not just the totals).

    Accepts any plan carrying ``row_src``/``run_len`` (CvmmPlan, GatherPlan,
    DedupGatherPlan). For a ``DedupGatherPlan`` the per-row baseline is the
    PRE-dedup selection count (one DMA per selected (token, slot) — what the
    flat GatherPlan would issue without run luck), so ``batching_factor``
    reports the full dedup+coalescing win; ``unique_rows`` records the
    post-dedup row count separately.

    ``verify=True`` additionally runs the plan through the static invariant
    oracle (repro.analysis.plans — the same checks CI's analysis gate applies)
    and raises ``ValueError`` on any violation, so benchmarks and property
    suites reporting stats on a plan prove its chunk table sound in the same
    call."""
    if verify:
        from ..analysis.plans import verify_plan
        findings = verify_plan(plan, n_rows)
        if findings:
            raise ValueError("plan invariant violations:\n" + "\n".join(
                f"  [{f.check}] {f.detail}" for f in findings))
    run_len = np.asarray(plan.run_len)
    batched = int((run_len > 0).sum())
    stats = {"chunk_hist": {str(int(s)): int((run_len == s).sum())
                            for s in _RUN_SIZES}}
    if isinstance(plan, DedupGatherPlan):
        per_row = int(plan.sel_pos.shape[0])
        stats["unique_rows"] = int((np.asarray(plan.row_src) < n_rows).sum())
    else:
        per_row = int((np.asarray(plan.row_src) < n_rows).sum())
    stats.update(per_row=per_row, run_batched=batched,
                 batching_factor=round(per_row / max(batched, 1), 3))
    return stats


def _float0(a: jax.Array):
    return np.zeros(a.shape, dtypes.float0)


def _pad_lane(a: jax.Array, axis: int) -> jax.Array:
    size = a.shape[axis]
    pad = round_up(size, LANE) - size
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pad_w(w: jax.Array) -> jax.Array:
    return _pad_lane(_pad_lane(w, 1), 2)


def _mask_empty(dw: jax.Array, group_sizes: jax.Array) -> jax.Array:
    # Blocks of experts with zero rows are never visited by the dW kernel
    # (their padded group has no tiles) and stay uninitialized.
    return jnp.where((group_sizes > 0)[:, None, None], dw, 0.0)


# ---------------------------------------------------------------------------
# Weighted row-gather plan (the framework's shared retrieval+aggregation
# primitive: PKM value lookup and the top-K MLP's sparse down-projection)
# ---------------------------------------------------------------------------

class GatherPlan(NamedTuple):
    """Layout metadata for one planned weighted row gather-sum.

    The expert_size-1 degenerate of ``CvmmPlan``: each selected "expert" is a
    single row of a value table (PKM values, W2 rows), so there is no grouped
    GEMM and no expert-pure tiling — only the run-batched streamed row-DMA
    pipeline, a per-slot weight, and the scatter back to tokens. Slots are in
    flat (token, slot) order padded to a TM multiple; the table is shared by
    forward and backward (custom_vjp residuals — no layout recompute). All
    int fields get float0 cotangents; ``weight_tiles`` is the one
    differentiable leaf (grads flow back to the selection scores)."""
    row_src: jax.Array       # (M_pad,) source row in the value table;
                             #   sentinel n_rows on slack slots
    tok_src: jax.Array       # (M_pad,) destination token of each slot;
                             #   sentinel n_tokens on slack
    run_start: jax.Array     # (M_pad,) per-tile DMA chunk table — same
    run_len: jax.Array       #   contract as CvmmPlan (ops._plan_runs)
    run_off: jax.Array       # (M_pad//TM * 9,) per-tile size-class bounds
    weight_tiles: jax.Array  # (M_pad//TM, TM) float32 weight per slot, 0 on
                             #   slack — fused into the gather epilogue

    @property
    def m_pad(self) -> int:
        return self.weight_tiles.shape[0] * TM


def make_gather_plan(idx: jax.Array, weights: jax.Array,
                     n_rows: int) -> GatherPlan:
    """Build the GatherPlan for one weighted aggregation call.

    idx (N, S) int row ids into a value table of ``n_rows`` rows, weights
    (N, S) aggregation weights. Differentiable in ``weights``. Slots keep the
    flat (token, s) order — no sort: there is no per-expert weight block to
    amortize, and the run batching still collapses whatever contiguity the
    selection happens to have."""
    n_tokens, s = idx.shape
    m = n_tokens * s
    m_pad = round_up(m, TM)
    row_src = jnp.pad(idx.reshape(-1).astype(jnp.int32), (0, m_pad - m),
                      constant_values=n_rows)
    tok_src = jnp.pad(jnp.repeat(jnp.arange(n_tokens, dtype=jnp.int32), s),
                      (0, m_pad - m), constant_values=n_tokens)
    run_start, run_len, run_off = _plan_runs(row_src, n_rows)
    w_pad = jnp.pad(weights.reshape(-1).astype(jnp.float32), (0, m_pad - m))
    return GatherPlan(row_src=row_src, tok_src=tok_src, run_start=run_start,
                      run_len=run_len, run_off=run_off,
                      weight_tiles=w_pad.reshape(m_pad // TM, TM))


def gather_supported(d_model: int, dtype=jnp.float32) -> bool:
    """Gate for the planned weighted-gather path: tile-level residency only.

    Mirrors ``fused_supported``/``pallas_supported`` for the streamed gather
    kernel — the value-table row count and the selection size never appear
    (both live in HBM); only a feature dim whose (TM, d_pad) tile working set
    cannot fit VMEM falls back to the XLA take+einsum rung."""
    return gather_tile_fits(round_up(d_model, LANE),
                            jnp.dtype(dtype).itemsize)


def _gws_impl(static, values_pad, row_src, tok_src, run_start, run_off,
              weight_tiles):
    n_tokens, fuse_weights, interpret, n_buffers = static
    if fuse_weights:
        rows = cvmm_gather_rows_pallas(values_pad, row_src, run_start, run_off,
                                       weight_tiles, interpret=interpret,
                                       n_buffers=n_buffers)
    else:
        # unfused rung: bare streamed gather, weight multiply at the XLA level
        rows = cvmm_gather_rows_pallas(values_pad, row_src, run_start, run_off,
                                       interpret=interpret,
                                       n_buffers=n_buffers)
        rows = (rows.astype(jnp.float32)
                * weight_tiles.reshape(-1)[:, None]).astype(rows.dtype)
    out = jnp.zeros((n_tokens, values_pad.shape[1]), rows.dtype)
    # slack slots carry the sentinel token — out of bounds, dropped here.
    return out.at[tok_src].add(rows, mode="drop")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gathered_weighted_sum(static, values_pad, row_src, tok_src, run_start,
                           run_off, weight_tiles):
    return _gws_impl(static, values_pad, row_src, tok_src, run_start, run_off,
                     weight_tiles)


def _gws_fwd(static, values_pad, row_src, tok_src, run_start, run_off,
             weight_tiles):
    y = _gws_impl(static, values_pad, row_src, tok_src, run_start, run_off,
                  weight_tiles)
    return y, (values_pad, row_src, tok_src, run_start, run_off, weight_tiles)


def _gws_bwd(static, res, dy):
    _, _, interpret, n_buffers = static
    values_pad, row_src, tok_src, run_start, run_off, weight_tiles = res
    w_flat = weight_tiles.reshape(-1)
    # Per-slot cotangent rows: sentinel tokens (slack) zero-fill.
    dy_rows = jnp.take(dy, tok_src, axis=0, mode="fill", fill_value=0)
    # dweight[s] = dy[tok[s]] . values[row_src[s]]: re-stream the un-weighted
    # gather through the same plan (the fused forward never materialized it).
    g = cvmm_gather_rows_pallas(values_pad, row_src, run_start, run_off,
                                interpret=interpret, n_buffers=n_buffers)
    dweights = jnp.sum(g.astype(jnp.float32) * dy_rows.astype(jnp.float32),
                       axis=1)
    dvalues = jnp.zeros_like(values_pad).at[row_src].add(
        (dy_rows.astype(jnp.float32) * w_flat[:, None]).astype(
            values_pad.dtype), mode="drop")
    return (dvalues, _float0(row_src), _float0(tok_src), _float0(run_start),
            _float0(run_off), dweights.reshape(weight_tiles.shape))


_gathered_weighted_sum.defvjp(_gws_fwd, _gws_bwd)


def gathered_weighted_sum(values: jax.Array, plan: GatherPlan, n_tokens: int,
                          *, fuse_weights: bool = True,
                          interpret: Optional[bool] = None,
                          n_buffers: Optional[int] = None) -> jax.Array:
    """Planned weighted row gather-sum: y[t] = sum_{s: tok[s]=t} w[s] * V[row[s]].

    The framework's shared retrieval+aggregation primitive executed through
    the streamed row-DMA pipeline: the value table stays unsorted in HBM
    (``pltpu.ANY``) and double-buffers (TM, d) row tiles through VMEM, so no
    (N, S, d) dense value gather is ever materialized at the XLA level. PKM
    value aggregation (V = the (n_values, d) value table, S = H*K) and the
    top-K MLP's sparse down-projection (V = W2 rows, S = K) both lower here
    via core/dispatch.weighted_value_sum. ``fuse_weights=False`` is the
    unfused rung: same streamed gather, weight multiply as an XLA pass.
    ``n_buffers`` (gather pipeline depth) is resolved through the tuner when
    omitted — depth 2 unless a tuned cache says deeper wins."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = values.shape[-1]
    if n_buffers is None:
        dec = autotune.gather_tiles(round_up(d, LANE),
                                    jnp.dtype(values.dtype).itemsize,
                                    budget=cvmm_mod.VMEM_BUDGET)
        n_buffers = dec.tiles["n_buffers"] if dec.tiles is not None else None
    y = _gathered_weighted_sum((n_tokens, fuse_weights, interpret, n_buffers),
                               _pad_lane(values, 1), plan.row_src,
                               plan.tok_src, plan.run_start, plan.run_off,
                               plan.weight_tiles)
    return y[:, :d]


# ---------------------------------------------------------------------------
# Deduplicated, value-index-sorted gather plan (the coalescing strategy:
# million-value PKM / shared-row selections)
# ---------------------------------------------------------------------------

class DedupGatherPlan(NamedTuple):
    """Layout metadata for one DEDUPLICATED weighted row gather-sum.

    Where ``GatherPlan`` keeps slots in flat (token, slot) order — one DMA
    slot per selection, shared rows copied once per selecting token — this
    plan is built from the value-index-SORTED UNION of the batch's
    selections: every row the batch touches appears exactly once, in
    ascending row order. Co-selected rows collapse to one DMA and adjacent
    value indices become real contiguous runs for ``_plan_runs`` to pack
    into multi-row descriptors, so the compacted block streams HBM->VMEM
    once regardless of how many tokens share it. Per-token weighting moves
    to a scatter-side index indirection: ``sel_pos`` maps each flat
    (token, slot) selection to its compacted slot, ``tok_src``/``weights``
    carry the destination token and weight. All int fields get float0
    cotangents; ``weights`` is the one differentiable leaf."""
    row_src: jax.Array    # (U_pad,) SORTED unique value rows; ascending,
                          #   sentinel n_rows on slack (sorts last, so the
                          #   valid prefix stays contiguous)
    run_start: jax.Array  # (U_pad,) per-tile DMA chunk table — same contract
    run_len: jax.Array    #   as CvmmPlan/GatherPlan (ops._plan_runs);
                          #   run_len is telemetry only
    run_off: jax.Array    # (U_pad//TM * 9,) per-tile size-class bounds
    sel_pos: jax.Array    # (M,) compacted slot of flat selection (token, s):
                          #   row_src[sel_pos[t*S+s]] == idx[t, s]
    tok_src: jax.Array    # (M,) destination token of each flat selection
    weights: jax.Array    # (M,) float32 per-selection weight — applied in
                          #   the scatter epilogue, not fused into the gather

    @property
    def u_pad(self) -> int:
        return self.row_src.shape[0]


def make_dedup_gather_plan(idx: jax.Array, weights: jax.Array,
                           n_rows: int) -> DedupGatherPlan:
    """Build the dedup/sorted plan for one weighted aggregation call.

    idx (N, S) int row ids into a value table of ``n_rows`` rows, weights
    (N, S) aggregation weights. Differentiable in ``weights``. The unique
    set is computed at a STATIC size (jit-safe): at most min(N*S, n_rows)
    distinct rows can exist, the remainder is sentinel slack. ``jnp.unique``
    returns the uniques ascending with the fill value appended at the end,
    which is exactly the sorted-prefix + sentinel-tail layout ``_plan_runs``
    wants."""
    n_tokens, s = idx.shape
    m = n_tokens * s
    u_cap = min(m, n_rows)
    u_pad = round_up(u_cap, TM)
    flat = idx.reshape(-1).astype(jnp.int32)
    uniq, inv = jnp.unique(flat, size=u_cap, fill_value=n_rows,
                           return_inverse=True)
    row_src = jnp.pad(uniq.astype(jnp.int32), (0, u_pad - u_cap),
                      constant_values=n_rows)
    run_start, run_len, run_off = _plan_runs(row_src, n_rows)
    tok_src = jnp.repeat(jnp.arange(n_tokens, dtype=jnp.int32), s)
    return DedupGatherPlan(row_src=row_src, run_start=run_start,
                           run_len=run_len, run_off=run_off,
                           sel_pos=inv.reshape(-1).astype(jnp.int32),
                           tok_src=tok_src,
                           weights=weights.reshape(-1).astype(jnp.float32))


def _gws_dedup_impl(static, values_pad, row_src, run_start, run_off, sel_pos,
                    tok_src, weights):
    n_tokens, interpret, n_buffers = static
    # One streamed pass over the COMPACTED block: U_pad slots, not M.
    rows = cvmm_gather_rows_pallas(values_pad, row_src, run_start, run_off,
                                   interpret=interpret, n_buffers=n_buffers)
    # Scatter-side indirection: expand compacted rows back to per-selection
    # rows (a (M,)-index take, feature-dim cheap vs the HBM row traffic the
    # dedup saved), weight, and scatter-add to tokens.
    sel_rows = jnp.take(rows, sel_pos, axis=0)             # (M, d_pad)
    wrows = (sel_rows.astype(jnp.float32) * weights[:, None]).astype(rows.dtype)
    out = jnp.zeros((n_tokens, values_pad.shape[1]), rows.dtype)
    return out.at[tok_src].add(wrows)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gathered_weighted_sum_dedup(static, values_pad, row_src, run_start,
                                 run_off, sel_pos, tok_src, weights):
    return _gws_dedup_impl(static, values_pad, row_src, run_start, run_off,
                           sel_pos, tok_src, weights)


def _gws_dedup_fwd(static, values_pad, row_src, run_start, run_off, sel_pos,
                   tok_src, weights):
    y = _gws_dedup_impl(static, values_pad, row_src, run_start, run_off,
                        sel_pos, tok_src, weights)
    return y, (values_pad, row_src, run_start, run_off, sel_pos, tok_src,
               weights)


def _gws_dedup_bwd(static, res, dy):
    _, interpret, n_buffers = static
    values_pad, row_src, run_start, run_off, sel_pos, tok_src, weights = res
    dy_rows = jnp.take(dy, tok_src, axis=0)                # (M, d_pad)
    # dweight[s] = dy[tok[s]] . V[idx[s]]: re-stream the compacted gather
    # through the same plan and expand via the indirection (the forward never
    # materialized the per-selection rows).
    rows = cvmm_gather_rows_pallas(values_pad, row_src, run_start, run_off,
                                   interpret=interpret, n_buffers=n_buffers)
    dweights = jnp.sum(jnp.take(rows, sel_pos, axis=0).astype(jnp.float32)
                       * dy_rows.astype(jnp.float32), axis=1)
    # dV two-level scatter: selections first accumulate into the COMPACTED
    # block (collisions only among tokens sharing a row), then the compacted
    # block scatters to the table — sentinel slack rows drop, and each table
    # row receives exactly one contribution.
    dcompact = jnp.zeros((row_src.shape[0], values_pad.shape[1]), jnp.float32
                         ).at[sel_pos].add(
        dy_rows.astype(jnp.float32) * weights[:, None])
    dvalues = jnp.zeros_like(values_pad).at[row_src].add(
        dcompact.astype(values_pad.dtype), mode="drop")
    return (dvalues, _float0(row_src), _float0(run_start), _float0(run_off),
            _float0(sel_pos), _float0(tok_src), dweights)


_gathered_weighted_sum_dedup.defvjp(_gws_dedup_fwd, _gws_dedup_bwd)


def gathered_weighted_sum_dedup(values: jax.Array, plan: DedupGatherPlan,
                                n_tokens: int, *,
                                interpret: Optional[bool] = None,
                                n_buffers: Optional[int] = None) -> jax.Array:
    """Planned weighted row gather-sum over the DEDUPLICATED selection union.

    Same contract as ``gathered_weighted_sum`` — y[t] = sum_s w[t,s] *
    V[idx[t,s]] — but the streamed pass covers each selected row ONCE (sorted
    ascending, so ``_plan_runs`` packs adjacent value indices into multi-row
    descriptors) and the per-token weights apply through the plan's
    scatter-side indirection. This is the production path for shared-row
    selections (PKM value aggregation: hot values are co-selected across the
    batch); at 1M+ values the HBM row traffic is the whole cost and dedup
    bounds it by min(N*S, rows-actually-touched). ``n_buffers`` resolves
    through the tuner's dedup-gather shape class when omitted."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = values.shape[-1]
    if n_buffers is None:
        dec = autotune.dedup_gather_tiles(round_up(d, LANE),
                                          jnp.dtype(values.dtype).itemsize,
                                          budget=cvmm_mod.VMEM_BUDGET)
        n_buffers = dec.tiles["n_buffers"] if dec.tiles is not None else None
    y = _gathered_weighted_sum_dedup((n_tokens, interpret, n_buffers),
                                     _pad_lane(values, 1), plan.row_src,
                                     plan.run_start, plan.run_off,
                                     plan.sel_pos, plan.tok_src, plan.weights)
    return y[:, :d]


# ---------------------------------------------------------------------------
# Tile decisions (one resolution per plan, threaded through custom_vjp)
# ---------------------------------------------------------------------------
# Each planned execution resolves its tile choices ONCE — at plan/dispatch
# time, through the tuner (kernels/autotune.py) against the call-time
# cvmm.VMEM_BUDGET — and threads them into every kernel launch of that call,
# forward and backward, as a hashable static argument. The kernels never
# re-query; "does any tile fit" (the capability gates below) and "which tile"
# are literally the same answer. Tiles stay OUT of the plan NamedTuples: plan
# fields are pytree leaves (traced under jit), tiles must stay static ints.

class FusedTiles(NamedTuple):
    """Static tile choices for one fused MoE-MLP call (fwd + bwd kernels)."""
    w1_tn: int        # fused w1, inference (single output)
    w1_train_tn: int  # fused w1 under vjp (writes preactivations too)
    t0_tn: int        # backward's gather(dy) @ w2^T streamed GEMM
    w2_tn: int        # w2 gate-epilogue fwd; also dX bwd (same shape key)
    dw_tb: int        # streamed dW blocked-width tile (dW1/dW1g/dW2 share it)
    w1_nb: int        # gather pipeline depths per streamed kernel; every
    w1_train_nb: int  # launch pairs a width with the depth from the SAME
    t0_nb: int        # tuner decision — mixing (w1_train_tn, w1_nb) was a
    dw_nb: int        # combination neither decision proved fits VMEM
    provenance: str   # "heuristic" | "tuned" (any constituent tuned -> tuned)


class PlannedTiles(NamedTuple):
    """Static tile choices for one planned unfused grouped GEMM (fwd + bwd)."""
    fwd_tn: int       # x @ w
    dx_tn: int        # g @ w^T
    dw_tk: int        # dW outer-product K tile
    dw_tn: int        # dW outer-product N tile
    provenance: str


def _merge_prov(*decisions) -> str:
    return ("tuned" if any(d.provenance == "tuned" for d in decisions)
            else "heuristic")


def fused_mlp_tiles(d_model: int, expert_size: int, dtype=jnp.float32,
                    glu: bool = False) -> Optional[FusedTiles]:
    """Resolve every tile the fused pipeline will launch (forward AND
    backward) for one shape class, or None when some kernel has no fitting
    tile. Reads ``cvmm.VMEM_BUDGET`` at call time (tests monkeypatch it)."""
    d_pad, g_pad = round_up(d_model, LANE), round_up(expert_size, LANE)
    b = jnp.dtype(dtype).itemsize
    budget = cvmm_mod.VMEM_BUDGET
    nw = 2 if glu else 1
    w1i = autotune.fused_w1_tiles(d_pad, g_pad, b, nw, 1, budget=budget)
    w1t = autotune.fused_w1_tiles(d_pad, g_pad, b, nw, 1 + nw, budget=budget)
    t0 = autotune.fused_w1_tiles(d_pad, g_pad, b, 1, 1, budget=budget)
    w2 = autotune.decide("pick_tn", {"k_pad": g_pad, "n_pad": d_pad, "b": b},
                         budget=budget)
    dw = autotune.streamed_dw_tiles(d_pad, g_pad, b, budget=budget)
    if any(d.tiles is None for d in (w1i, w1t, t0, w2, dw)):
        return None
    return FusedTiles(
        w1_tn=w1i.tiles["tn"], w1_train_tn=w1t.tiles["tn"],
        t0_tn=t0.tiles["tn"], w2_tn=w2.tiles["tn"], dw_tb=dw.tiles["tb"],
        w1_nb=w1i.tiles["n_buffers"], w1_train_nb=w1t.tiles["n_buffers"],
        t0_nb=t0.tiles["n_buffers"], dw_nb=dw.tiles["n_buffers"],
        provenance=_merge_prov(w1i, w1t, t0, w2, dw))


def planned_call_tiles(k_dim: int, n_dim: int,
                       dtype=jnp.float32) -> Optional[PlannedTiles]:
    """Resolve the four grouped-GEMM tiles one planned unfused call launches
    (fwd, dX, and the two dW tiles), or None when any has no fitting tile."""
    k_pad, n_pad = round_up(k_dim, LANE), round_up(n_dim, LANE)
    b = jnp.dtype(dtype).itemsize
    budget = cvmm_mod.VMEM_BUDGET
    picks = [autotune.decide("pick_tn", {"k_pad": kp, "n_pad": npad, "b": b},
                             budget=budget)
             for kp, npad in ((k_pad, n_pad), (n_pad, k_pad),
                              (TM, k_pad), (TM, n_pad))]
    if any(d.tiles is None for d in picks):
        return None
    fwd, dx, dwk, dwn = picks
    return PlannedTiles(fwd_tn=fwd.tiles["tn"], dx_tn=dx.tiles["tn"],
                        dw_tk=dwk.tiles["tn"], dw_tn=dwn.tiles["tn"],
                        provenance=_merge_prov(*picks))


class SortKernelPlan(NamedTuple):
    """The sort path's execution decision for one shape class: which rung of
    the capability chain runs AND with what tiles — one resolution, consumed
    by core/dispatch._sort_path. ``rung`` is "pallas_fused", "pallas", or
    "ragged" (some tile working set cannot fit VMEM at any size, or the
    activation is not tile-local: degrade to XLA's grouped matmul)."""
    rung: str
    fused: Optional[FusedTiles]          # set iff rung == "pallas_fused"
    planned_w1: Optional[PlannedTiles]   # unfused w1/w1g calls (K=d, N=g)
    planned_w2: Optional[PlannedTiles]   # unfused w2 call (K=g, N=d)

    @property
    def provenance(self) -> str:
        if self.fused is not None:
            return self.fused.provenance
        if self.planned_w1 is not None:
            return _merge_prov(self.planned_w1, self.planned_w2)
        return "none"


def plan_sort_kernels(impl: str, d_model: int, expert_size: int,
                      activation: str, dtype=jnp.float32,
                      glu: bool = False) -> SortKernelPlan:
    """Resolve the sort path's rung and tiles in ONE place.

    Mirrors the old inline gate chain in core/dispatch._sort_path —
    ``pallas_supported`` decides pallas vs ragged, ``fused_supported`` decides
    fused vs unfused — but the same tuner queries that answer "does any tile
    fit" now also return WHICH tile, so degradation decisions and tile
    choices can never disagree."""
    if not impl.startswith("pallas"):
        return SortKernelPlan(rung="ragged", fused=None, planned_w1=None,
                              planned_w2=None)
    pw1 = planned_call_tiles(d_model, expert_size, dtype)
    pw2 = planned_call_tiles(expert_size, d_model, dtype)
    if pw1 is None or pw2 is None:
        # matches pallas_supported() is False: even tn=128 exhausts VMEM for
        # some launch — degrade to XLA's grouped matmul, don't raise at trace.
        return SortKernelPlan(rung="ragged", fused=None, planned_w1=None,
                              planned_w2=None)
    if impl.startswith("pallas_fused") and activation in FUSIBLE_ACTIVATIONS:
        ft = fused_mlp_tiles(d_model, expert_size, dtype, glu)
        if ft is not None:
            return SortKernelPlan(rung="pallas_fused", fused=ft,
                                  planned_w1=pw1, planned_w2=pw2)
    return SortKernelPlan(rung="pallas", fused=None, planned_w1=pw1,
                          planned_w2=pw2)


# ---------------------------------------------------------------------------
# Unfused pallas path with plan-threaded custom_vjp
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _cvmm_planned(x, new_pos, tile_expert, group_sizes, w, interpret,
                  tiles=None):
    return _planned_fwd(x, new_pos, tile_expert, group_sizes, w, interpret,
                        tiles)[0]


def _planned_fwd(x, new_pos, tile_expert, group_sizes, w, interpret,
                 tiles=None):
    n = w.shape[2]
    m_pad = tile_expert.shape[0] * TM
    x_pad = jnp.zeros((m_pad, round_up(x.shape[1], LANE)), x.dtype)
    x_pad = x_pad.at[new_pos].set(_pad_lane(x, 1))
    out_pad = cvmm_pallas(x_pad, tile_expert, _pad_w(w), interpret=interpret,
                          tn=None if tiles is None else tiles.fwd_tn)
    # Residuals carry the plan arrays AND the padded activations: backward does
    # zero layout recompute and pads only the incoming cotangent.
    return out_pad[new_pos, :n], (x_pad, new_pos, tile_expert, group_sizes, w)


def _planned_bwd(interpret, tiles, res, g):
    x_pad, new_pos, tile_expert, group_sizes, w = res
    e, k, n = w.shape
    m_pad = x_pad.shape[0]
    g_pad = jnp.zeros((m_pad, round_up(n, LANE)), g.dtype)
    g_pad = g_pad.at[new_pos].set(_pad_lane(g, 1))
    w_pad = _pad_w(w)
    dx_pad = cvmm_pallas(g_pad, tile_expert, jnp.swapaxes(w_pad, 1, 2),
                         interpret=interpret,
                         tn=None if tiles is None else tiles.dx_tn)
    dx = dx_pad[new_pos, :k].astype(x_pad.dtype)
    dw = cvmm_dw_pallas(x_pad, tile_expert, g_pad, e, interpret=interpret,
                        tk=None if tiles is None else tiles.dw_tk,
                        tn=None if tiles is None else tiles.dw_tn)
    dw = _mask_empty(dw, group_sizes)[:, :k, :n].astype(w.dtype)
    return (dx, _float0(new_pos), _float0(tile_expert), _float0(group_sizes),
            dw)


_cvmm_planned.defvjp(_planned_fwd, _planned_bwd)


def cvmm_planned(x: jax.Array, plan: CvmmPlan, w: jax.Array,
                 *, interpret: bool,
                 tiles: Optional[PlannedTiles] = None) -> jax.Array:
    """Grouped matmul on *sorted* rows reusing a precomputed plan (no layout
    derivation inside — three calls in an MoE layer share one plan). ``tiles``
    threads a pre-resolved tile decision into every launch of this call;
    omitted -> the kernels fall back to per-launch heuristic queries."""
    return _cvmm_planned(x, plan.new_pos, plan.tile_expert, plan.group_sizes,
                         w.astype(x.dtype), interpret, tiles)


# ---------------------------------------------------------------------------
# Fused MoE-MLP pipeline (gather -> grouped GEMM -> epilogue)
# ---------------------------------------------------------------------------

def fused_supported(n_tokens: int, d_model: int, expert_size: int,
                    activation: str, dtype=jnp.float32,
                    glu: bool = False) -> bool:
    """Gate for the fused pipeline: TILE-level residency only.

    The streamed kernels keep the unsorted arrays in HBM and double-buffer
    (TM, K) row tiles through VMEM, so the token count never appears in the
    residency check (``n_tokens`` is kept in the signature for
    callers/telemetry but cannot flip the answer). Callers fall back to the
    unfused path only when the activation is not tile-local or some per-step
    tile working set cannot fit at any tile size (huge d_model /
    expert_size). Sized for the worst case (training): the save_preact w1
    launch, the w2 / dX grouped GEMMs, and the streamed dW kernels — every
    kernel the fused forward AND backward will compile."""
    del n_tokens  # streamed: any row count is supported
    if activation not in FUSIBLE_ACTIVATIONS:
        return False
    return fused_mlp_tiles(d_model, expert_size, dtype, glu) is not None


def pallas_supported(d_model: int, expert_size: int, dtype=jnp.float32) -> bool:
    """Gate for the UNFUSED pallas path's tile working sets.

    ``_pick_tn`` no longer silently under-tiles: it returns None when even
    tn=128 exceeds the VMEM budget, and the kernels raise. Every grouped GEMM
    the unfused path launches (w1/w2 forward, dX, and the dW outer products)
    must therefore find a fitting tile; when this returns False, dispatchers
    should fall back to the XLA-native "ragged" impl instead of compiling a
    kernel that raises at trace time (huge d_model / expert_size configs).
    Same resolution as ``planned_call_tiles`` — the capability answer and the
    tile choice are one query."""
    return planned_call_tiles(d_model, expert_size, dtype) is not None


def _fused_fwd_impl(static, xf, plan, w1, w1g, w2, save_preact=False):
    act_name, interpret, tiles = static
    n, d = xf.shape
    # Lane-pad the feature dim only: the streamed kernel gathers rows straight
    # out of HBM, so no row-count padding is needed (sentinel row_src == n).
    xe = _pad_lane(xf, 1)
    w1_tn = w1_nb = w2_tn = None
    if tiles is not None:
        w1_tn = tiles.w1_train_tn if save_preact else tiles.w1_tn
        w1_nb = tiles.w1_train_nb if save_preact else tiles.w1_nb
        w2_tn = tiles.w2_tn
    w1_out = cvmm_fused_w1_pallas(
        xe, plan.row_src, plan.run_start, plan.run_off, plan.tile_expert,
        _pad_w(w1), _pad_w(w1g) if w1g is not None else None,
        act_name=act_name, save_preact=save_preact, interpret=interpret,
        tn=w1_tn, n_buffers=w1_nb)
    u_pad = w1_out[0] if save_preact else w1_out
    y_pad = cvmm_fused_w2_pallas(u_pad, plan.tile_expert, _pad_w(w2),
                                 plan.gate_tiles, interpret=interpret,
                                 tn=w2_tn)
    # row_src slack slots hold the sentinel n — out of bounds, dropped here.
    y = jnp.zeros((n, d), y_pad.dtype).at[plan.row_src].add(
        y_pad[:, :d], mode="drop")
    return y, xe, w1_out


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_mlp_fused(static, xf, plan, w1, w1g, w2):
    return _fused_fwd_impl(static, xf, plan, w1, w1g, w2)[0]


def _fused_fwd(static, xf, plan, w1, w1g, w2):
    # Under differentiation the w1 kernel also emits the pre-activations in the
    # same grid pass (one extra HBM write each) so backward runs zero recompute
    # GEMMs; the inference/primal path keeps the lean single-output kernel.
    y, xe, w1_out = _fused_fwd_impl(static, xf, plan, w1, w1g, w2,
                                    save_preact=True)
    preact = w1_out[1:]                                   # (h,) or (h, hg)
    return y, (xe, plan, w1, w1g, w2, preact, xf.shape)


def _fused_bwd(static, res, dy):
    act_name, interpret, tiles = static
    xe, plan, w1, w1g, w2, preact, (n, d) = res
    t0_tn = t0_nb = dx_tn = dw_tb = dw_nb = None
    if tiles is not None:
        t0_tn, t0_nb = tiles.t0_tn, tiles.t0_nb
        dx_tn = tiles.w2_tn              # dX shares the (g_pad, d_pad) key
        dw_tb, dw_nb = tiles.dw_tb, tiles.dw_nb
    act = act_fn(act_name)
    e, _, gsz = w1.shape
    w1p, w2p = _pad_w(w1), _pad_w(w2)
    w1gp = _pad_w(w1g) if w1g is not None else None
    m_pad = plan.m_pad
    gate = plan.gate_tiles.reshape(m_pad)[:, None]        # (M_pad, 1) f32
    runs = (plan.row_src, plan.run_start, plan.run_off, plan.tile_expert)

    # Gather-free backward: the unsorted cotangent and activations stay in
    # HBM and stream through the same run-batched row-DMA plan as forward —
    # no tile-aligned (M_pad, K) copy of either is ever materialized.
    dy_e = _pad_lane(dy, 1)
    # t0 = gather(dy) @ w2^T: the streamed fused kernel with an identity
    # epilogue (slack rows zero-fill -> t0 slack rows are exactly zero).
    t0 = cvmm_fused_w1_pallas(dy_e, *runs, jnp.swapaxes(w2p, 1, 2), None,
                              act_name="identity", interpret=interpret,
                              tn=t0_tn, n_buffers=t0_nb)
    if w1g is not None:
        h, hg = preact
        u, eltwise_vjp = jax.vjp(lambda a, b: act(a) * b, h, hg)
    else:
        (h,) = preact
        u, eltwise_vjp = jax.vjp(act, h)

    # d/dgate[r] = dy[r] . (u[r] @ w2[e]) == (dy[r] @ w2[e]^T) . u[r] = t0 . u
    dgate = jnp.sum(t0.astype(jnp.float32) * u.astype(jnp.float32), axis=1)
    du = (t0.astype(jnp.float32) * gate).astype(u.dtype)
    if w1g is not None:
        dh, dhg = eltwise_vjp(du)
    else:
        (dh,) = eltwise_vjp(du)

    # dW2 streams dy (g-operand) and fuses the gate multiply; dW1/dW1g stream
    # the activations (x-operand). Both pull straight from pltpu.ANY HBM.
    dw2 = _mask_empty(
        cvmm_dw_streamed_pallas(u, dy_e, *runs, e, stream_x=False,
                                gate_tiles=plan.gate_tiles,
                                interpret=interpret, tb=dw_tb,
                                n_buffers=dw_nb),
        plan.group_sizes)[:, :gsz, :d].astype(w2.dtype)
    dw1 = _mask_empty(
        cvmm_dw_streamed_pallas(xe, dh, *runs, e, stream_x=True,
                                interpret=interpret, tb=dw_tb,
                                n_buffers=dw_nb),
        plan.group_sizes)[:, :d, :gsz].astype(w1.dtype)
    dx_pad = cvmm_pallas(dh, plan.tile_expert, jnp.swapaxes(w1p, 1, 2),
                         interpret=interpret, tn=dx_tn)
    if w1g is not None:
        dw1g = _mask_empty(
            cvmm_dw_streamed_pallas(xe, dhg, *runs, e, stream_x=True,
                                    interpret=interpret, tb=dw_tb,
                                    n_buffers=dw_nb),
            plan.group_sizes)[:, :d, :gsz].astype(w1g.dtype)
        dx_pad = dx_pad + cvmm_pallas(dhg, plan.tile_expert,
                                      jnp.swapaxes(w1gp, 1, 2),
                                      interpret=interpret, tn=dx_tn)
    else:
        dw1g = None

    dxf = jnp.zeros((n, xe.shape[1]), dx_pad.dtype).at[plan.row_src].add(
        dx_pad, mode="drop")[:, :d].astype(xe.dtype)
    dplan = CvmmPlan(
        perm=_float0(plan.perm), group_sizes=_float0(plan.group_sizes),
        new_pos=_float0(plan.new_pos), row_src=_float0(plan.row_src),
        run_start=_float0(plan.run_start), run_len=_float0(plan.run_len),
        run_off=_float0(plan.run_off), tile_expert=_float0(plan.tile_expert),
        gate_tiles=dgate.reshape(plan.gate_tiles.shape))
    return dxf, dplan, dw1, dw1g, dw2


_moe_mlp_fused.defvjp(_fused_fwd, _fused_bwd)


def moe_mlp_fused(xf: jax.Array, plan: CvmmPlan, w1: jax.Array, w2: jax.Array,
                  w1g: Optional[jax.Array] = None, *, activation: str = "relu",
                  interpret: Optional[bool] = None,
                  tiles: Optional[FusedTiles] = None) -> jax.Array:
    """Fused dropless expert MLP: y[t] = gate * (act(x @ w1[e]) [* x @ w1g[e]]) @ w2[e].

    xf (N, d) UNSORTED activations; the gather, activation/GLU and gate multiply
    all run inside the two kernel launches (see kernels/cvmm.py). Returns the
    per-(token, expert) outputs already scatter-added back to (N, d).

    ``tiles`` threads one pre-resolved ``FusedTiles`` decision (dispatch /
    ``fused_mlp_tiles``) through every launch of this call, forward and
    backward; omitted -> resolved here once per trace (identical answer when
    tuning is disabled)."""
    if activation not in FUSIBLE_ACTIVATIONS:
        raise ValueError(f"activation {activation!r} is not tile-local; "
                         f"fusible: {FUSIBLE_ACTIVATIONS}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dt = xf.dtype
    if tiles is None:
        tiles = fused_mlp_tiles(w1.shape[1], w1.shape[2], dt,
                                glu=w1g is not None)
    return _moe_mlp_fused((activation, interpret, tiles), xf, plan,
                          w1.astype(dt),
                          None if w1g is None else w1g.astype(dt),
                          w2.astype(dt))


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def cvmm(x: jax.Array, group_sizes: jax.Array, w: jax.Array,
         impl: Optional[str] = None) -> jax.Array:
    """Grouped matmul: rows of x (sorted by expert, sizes in group_sizes) times
    w (E, K, N). Returns (rows, N)."""
    impl = impl or default_impl()
    if impl == "ragged":
        return jax.lax.ragged_dot(x, w.astype(x.dtype),
                                  group_sizes.astype(jnp.int32))
    if impl == "ref":
        return refk.cvmm_ref(x, group_sizes, w)
    if impl in ("pallas", "pallas_interpret", "pallas_fused",
                "pallas_fused_interpret"):
        new_pos, tile_expert, _ = _tile_layout(group_sizes, x.shape[0],
                                               w.shape[0])
        return _cvmm_planned(x, new_pos, tile_expert,
                             group_sizes.astype(jnp.int32), w.astype(x.dtype),
                             _impl_interpret(impl),
                             planned_call_tiles(x.shape[1], w.shape[2],
                                                x.dtype))
    raise ValueError(f"unknown cvmm impl {impl}")


# ---------------------------------------------------------------------------
# Decode-shaped planned CVMM (serving: tiny-M steps on a cached skeleton)
# ---------------------------------------------------------------------------
# A continuous-batching decode step routes a handful of rows (one token per
# in-flight request, K=1-2), so rebuilding a full ``make_moe_plan`` —
# argsort, tile layout, chunk-table derivation — every token is pure
# overhead: at fixed (n_tokens, k, e, d, g) the expensive pieces of the plan
# do not depend on the routing at all. ``DecodePlan`` is that routing-free
# skeleton, built once per decode shape class and cached by the serving
# layer (serving/decode_plan.DecodePlanCache); the only per-step work is
# ``decode_slots`` — a one-hot rank giving each selection its slot inside a
# dropless per-expert capacity region — which is a few tiny XLA ops inside
# the jitted step, not a plan rebuild.

class DecodePlan(NamedTuple):
    """Routing-free layout skeleton for one decode shape class.

    The per-expert capacity is the dropless worst case ``cap =
    round_up(n_tokens*k, TM)`` (every selection could route to one expert),
    so the padded row space is ``m_pad = n_experts * cap`` and
    ``tile_expert`` is the STATIC ``repeat(arange(e), cap//TM)`` — expert
    boundaries never move with the routing, which is what lets the grouped
    GEMMs launch against a cached layout. ``gather`` is the decode-shaped
    dedup plan over TOKEN rows (row_src == arange(n_tokens)): each token's
    activation row streams HBM->VMEM once and the K-way expansion happens
    through the plan's ``sel_pos`` indirection, not K duplicate row DMAs.
    ``w1_tn``/``w2_tn`` come from the tuner's "decode_gemm" shape class —
    tile decisions costed at ONE row tile instead of a training pass.
    Execution is forward-only (inference); grads never flow through it."""
    n_tokens: int
    k: int
    n_experts: int
    cap: int                     # per-expert slot capacity (TM multiple)
    tile_expert: jax.Array       # (n_experts * cap // TM,) static layout
    gather: DedupGatherPlan      # token-row dedup gather (row_src = arange)
    gather_nb: Optional[int]     # pipeline depth for the gather kernel
    w1_tn: int                   # decode_gemm tile widths (w1: d->g, w2: g->d)
    w2_tn: int
    provenance: str

    @property
    def m_pad(self) -> int:
        return self.n_experts * self.cap


def make_decode_plan(n_tokens: int, k: int, n_experts: int, d_model: int,
                     expert_size: int,
                     dtype=jnp.float32) -> Optional[DecodePlan]:
    """Build the routing-free decode skeleton for one shape class, or None
    when some launch has no fitting tile (callers fall back to the per-call
    ``make_moe_plan`` path). Reads ``cvmm.VMEM_BUDGET`` at call time."""
    b = jnp.dtype(dtype).itemsize
    d_pad = round_up(d_model, LANE)
    g_pad = round_up(expert_size, LANE)
    budget = cvmm_mod.VMEM_BUDGET
    w1 = autotune.decode_gemm_tiles(d_pad, g_pad, b, budget=budget)
    w2 = autotune.decode_gemm_tiles(g_pad, d_pad, b, budget=budget)
    gnb = autotune.dedup_gather_tiles(d_pad, b, budget=budget)
    if w1.tiles is None or w2.tiles is None:
        return None
    cap = round_up(n_tokens * k, TM)
    tile_expert = jnp.repeat(jnp.arange(n_experts, dtype=jnp.int32),
                             cap // TM)
    tok = jnp.broadcast_to(jnp.arange(n_tokens, dtype=jnp.int32)[:, None],
                           (n_tokens, k))
    gather = make_dedup_gather_plan(tok, jnp.ones((n_tokens, k), jnp.float32),
                                    n_tokens)
    return DecodePlan(
        n_tokens=n_tokens, k=k, n_experts=n_experts, cap=cap,
        tile_expert=tile_expert, gather=gather,
        gather_nb=None if gnb.tiles is None else gnb.tiles["n_buffers"],
        w1_tn=w1.tiles["tn"], w2_tn=w2.tiles["tn"],
        provenance=_merge_prov(w1, w2))


def decode_slots(plan: DecodePlan, idx: jax.Array) -> jax.Array:
    """The per-step incremental plan update: flat selection -> padded slot.

    A cumulative one-hot rank orders each selection within its expert;
    ``slot = expert*cap + rank`` lands it in the expert's static capacity
    region. Dropless by construction (rank < n*k <= cap), injective (ranks
    are distinct per expert), and a few tiny ops at decode M — this is ALL
    the per-step work the cached skeleton leaves."""
    e_flat = idx.reshape(-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(e_flat, plan.n_experts, dtype=jnp.int32)
    rank = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    return e_flat * plan.cap + rank


def dedup_gather_rows(values: jax.Array, plan: DedupGatherPlan, *,
                      interpret: Optional[bool] = None,
                      n_buffers: Optional[int] = None) -> jax.Array:
    """Per-selection row gather through a dedup plan: rows[s] = V[idx[s]].

    The streamed pass covers the plan's compacted union once (shared rows
    one DMA) and the (M,)-index ``sel_pos`` take expands back to selection
    order — ``gathered_weighted_sum_dedup`` without the weight/scatter
    epilogue, for callers that need the rows themselves (the decode MoE
    path scatters them into expert-capacity slots instead of summing).
    Forward-only: no custom_vjp, grads do not flow through it."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if n_buffers is None:
        dec = autotune.dedup_gather_tiles(round_up(values.shape[-1], LANE),
                                          jnp.dtype(values.dtype).itemsize,
                                          budget=cvmm_mod.VMEM_BUDGET)
        n_buffers = dec.tiles["n_buffers"] if dec.tiles is not None else None
    rows = cvmm_gather_rows_pallas(_pad_lane(values, 1), plan.row_src,
                                   plan.run_start, plan.run_off,
                                   interpret=interpret, n_buffers=n_buffers)
    return jnp.take(rows, plan.sel_pos, axis=0)


def moe_mlp_decode(xf: jax.Array, idx: jax.Array, gates: jax.Array,
                   plan: DecodePlan, w1: jax.Array, w2: jax.Array,
                   w1g: Optional[jax.Array] = None, *,
                   activation: str = "relu",
                   interpret: Optional[bool] = None) -> jax.Array:
    """Decode-shaped MoE MLP on a cached skeleton: y[t] = sum_k g[t,k] *
    w2[e]^T act(w1[e]^T x[t]) without any per-step plan rebuild.

    xf (n, d) tokens, idx/gates (n, k) routing. Token rows stream once
    through the skeleton's dedup gather, scatter into the static
    expert-capacity layout, run the two grouped GEMMs at the decode-tuned
    tile widths, and combine back with the gates. Matches the sort path's
    math exactly (dropless). Forward-only — serving installs it via
    ``core.dispatch.set_decode_provider`` for inference traces only."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = xf.shape
    assert n == plan.n_tokens and idx.shape == (n, plan.k)
    d_pad = round_up(d, LANE)
    x_rows = dedup_gather_rows(xf, plan.gather, interpret=interpret,
                               n_buffers=plan.gather_nb)      # (n*k, d_pad)
    slot = decode_slots(plan, idx)
    x_pad = jnp.zeros((plan.m_pad, d_pad), xf.dtype).at[slot].set(x_rows)
    h = cvmm_pallas(x_pad, plan.tile_expert, _pad_w(w1.astype(xf.dtype)),
                    interpret=interpret, tn=plan.w1_tn)
    # Activation at the XLA level; padded weight columns are zero, so acting
    # on them is harmless (w2's padded K rows are zero either way).
    u = act_fn(activation)(h)
    if w1g is not None:
        hg = cvmm_pallas(x_pad, plan.tile_expert,
                         _pad_w(w1g.astype(xf.dtype)),
                         interpret=interpret, tn=plan.w1_tn)
        u = u * hg
    y_pad = cvmm_pallas(u.astype(xf.dtype), plan.tile_expert,
                        _pad_w(w2.astype(xf.dtype)),
                        interpret=interpret, tn=plan.w2_tn)
    g_flat = gates.reshape(-1).astype(jnp.float32)
    rows = y_pad[slot].astype(jnp.float32) * g_flat[:, None]  # (n*k, d_pad)
    y = jnp.zeros((n, d_pad), jnp.float32).at[plan.gather.tok_src].add(rows)
    return y[:, :d].astype(xf.dtype)


def assemble_decode_plan(plan: DecodePlan, idx: jax.Array,
                         gates: jax.Array) -> CvmmPlan:
    """Materialize the full ``CvmmPlan`` the skeleton + one routing imply.

    The hot path never needs this — ``moe_mlp_decode`` runs straight off the
    skeleton — but the analysis plans pass and the serve bench verify the
    decode layout against the SAME invariant oracle as every other plan
    (tile purity, slot injection, chunk-table replay), so the cached-
    skeleton shortcut can never drift from the contract silently. Slots
    follow ``decode_slots``; the chunk table is derived from the scattered
    ``row_src`` exactly as ``make_moe_plan`` would."""
    k = idx.shape[-1]
    e_flat = idx.reshape(-1).astype(jnp.int32)
    g_flat = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(plan.n_tokens, dtype=jnp.int32), k)
    perm = jnp.argsort(e_flat, stable=True)
    group_sizes = jnp.bincount(e_flat,
                               length=plan.n_experts).astype(jnp.int32)
    new_pos = decode_slots(plan, idx)[perm]
    row_src = jnp.full((plan.m_pad,), plan.n_tokens,
                       jnp.int32).at[new_pos].set(tok[perm])
    run_start, run_len, run_off = _plan_runs(row_src, plan.n_tokens)
    gate_pad = jnp.zeros((plan.m_pad,), jnp.float32).at[new_pos].set(
        g_flat[perm].astype(jnp.float32))
    return CvmmPlan(perm=perm, group_sizes=group_sizes, new_pos=new_pos,
                    row_src=row_src, run_start=run_start, run_len=run_len,
                    run_off=run_off, tile_expert=plan.tile_expert,
                    gate_tiles=gate_pad.reshape(plan.m_pad // TM, TM))
