"""jit-ready CVMM wrapper: layout transformation + backend dispatch + custom_vjp.

Backends
--------
"pallas"   The TPU kernel (cvmm.py). On CPU it runs in interpret mode (the kernel body
           executes in Python) — used by the test suite to validate the kernel logic.
"ragged"   jax.lax.ragged_dot — XLA's grouped matmul; differentiable; the default on
           CPU and a correctness cross-check on TPU.
"ref"      Pure-jnp one-hot oracle (kernels/ref.py), O(N*E) — tests only.

The public ``cvmm(x, group_sizes, w)`` takes rows already *sorted by expert*
(group_sizes sums to rows) and returns x[i] @ w[expert(i)].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes

from ..common import round_up
from . import ref as refk
from .cvmm import TM, LANE, cvmm_dw_pallas, cvmm_pallas

_FORCED_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    global _FORCED_IMPL
    _FORCED_IMPL = impl


def default_impl() -> str:
    if _FORCED_IMPL:
        return _FORCED_IMPL
    return "pallas" if jax.default_backend() == "tpu" else "ragged"


# ---------------------------------------------------------------------------
# Tile-aligned layout (megablocks-style)
# ---------------------------------------------------------------------------

def _tile_layout(group_sizes: jax.Array, m: int, e: int):
    """Map sorted rows to a layout where each expert's range is TM-aligned.

    Returns (new_pos (m,), tile_expert (m_pad//TM,), m_pad). m_pad is a static
    upper bound m + e*TM; slack tiles are all-zero and clamped to the last expert.
    """
    gs = group_sizes.astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(gs)])[:-1]
    ps = ((gs + TM - 1) // TM) * TM                       # padded group sizes
    offs_p = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(ps)])[:-1]
    rows = jnp.arange(m, dtype=jnp.int32)
    re = refk.row_experts(gs, m).astype(jnp.int32)
    new_pos = offs_p[re] + (rows - offs[re])
    m_pad = round_up(m, TM) + e * TM
    n_tiles = m_pad // TM
    ends_p = jnp.cumsum(ps)
    tile_expert = jnp.searchsorted(ends_p, jnp.arange(n_tiles, dtype=jnp.int32) * TM,
                                   side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, e - 1)         # clamp slack tiles
    return new_pos, tile_expert, m_pad


def _pad_lane(a: jax.Array, axis: int) -> jax.Array:
    size = a.shape[axis]
    pad = round_up(size, LANE) - size
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


# ---------------------------------------------------------------------------
# Pallas path with custom_vjp
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _cvmm_pallas_vjp(x, group_sizes, w, interpret):
    return _pallas_fwd_impl(x, group_sizes, w, interpret)


def _pallas_fwd_impl(x, group_sizes, w, interpret):
    m, k = x.shape
    e, _, n = w.shape
    new_pos, tile_expert, m_pad = _tile_layout(group_sizes, m, e)
    x_pad = jnp.zeros((m_pad, round_up(k, LANE)), x.dtype)
    x_pad = x_pad.at[new_pos].set(_pad_lane(x, 1))
    w_pad = _pad_lane(_pad_lane(w, 1), 2)
    out_pad = cvmm_pallas(x_pad, tile_expert, w_pad, interpret=interpret)
    return out_pad[new_pos, :n]


def _pallas_fwd(x, group_sizes, w, interpret):
    return _pallas_fwd_impl(x, group_sizes, w, interpret), (x, group_sizes, w)


def _pallas_bwd(interpret, res, g):
    x, group_sizes, w = res
    m, k = x.shape
    e, _, n = w.shape
    # dX: same grouped matmul against w^T.
    dx = _pallas_fwd_impl(g, group_sizes, jnp.swapaxes(w, 1, 2), interpret)
    # dW: grouped outer-product accumulation kernel on the tile-aligned layout.
    new_pos, tile_expert, m_pad = _tile_layout(group_sizes, m, e)
    x_pad = jnp.zeros((m_pad, round_up(k, LANE)), x.dtype)
    x_pad = x_pad.at[new_pos].set(_pad_lane(x, 1))
    g_pad = jnp.zeros((m_pad, round_up(n, LANE)), g.dtype)
    g_pad = g_pad.at[new_pos].set(_pad_lane(g, 1))
    dw = cvmm_dw_pallas(x_pad, tile_expert, g_pad, e, interpret=interpret)
    # Blocks of experts with zero rows are never visited by the kernel (their padded
    # group has no tiles) and stay uninitialized -- mask them to zero explicitly.
    dw = jnp.where((group_sizes > 0)[:, None, None], dw, 0.0)
    dw = dw[:, :k, :n].astype(w.dtype)
    d_gs = np.zeros(group_sizes.shape, dtypes.float0)
    return dx.astype(x.dtype), d_gs, dw


_cvmm_pallas_vjp.defvjp(_pallas_fwd, _pallas_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def cvmm(x: jax.Array, group_sizes: jax.Array, w: jax.Array,
         impl: Optional[str] = None) -> jax.Array:
    """Grouped matmul: rows of x (sorted by expert, sizes in group_sizes) times
    w (E, K, N). Returns (rows, N)."""
    impl = impl or default_impl()
    if impl == "ragged":
        return jax.lax.ragged_dot(x, w.astype(x.dtype),
                                  group_sizes.astype(jnp.int32))
    if impl == "ref":
        return refk.cvmm_ref(x, group_sizes, w)
    if impl == "pallas":
        return _cvmm_pallas_vjp(x, group_sizes, w.astype(x.dtype),
                                jax.default_backend() != "tpu")
    if impl == "pallas_interpret":
        return _cvmm_pallas_vjp(x, group_sizes, w.astype(x.dtype), True)
    raise ValueError(f"unknown cvmm impl {impl}")
