"""Pallas TPU kernels for CVMM — conditional (grouped) matmul, the paper's CUDA
kernel adapted to the TPU memory hierarchy (DESIGN.md Sec. 4).

Layout contract (established by ops.py, shared by every kernel here)
--------------------------------------------------------------------
Rows are sorted by expert and each expert's row-range is padded to a multiple of
the row tile TM, so **every (TM, K) row tile belongs to exactly one expert**.
ops.py computes this layout ONCE per MoE call into a ``CvmmPlan``:

  ``new_pos``     (M,)        tile-aligned slot of sorted row i
  ``row_src``     (M_pad,)    source row in the *unsorted* activations for each
                              padded slot; slack slots hold the sentinel N (one
                              past the last row) so XLA-side scatters drop them
  ``tile_expert`` (M_pad/TM,) row-tile index -> expert id (non-decreasing)
  ``gate_tiles``  (M_pad/TM, TM) float32 gate per padded slot, 0 on slack

``tile_expert`` is scalar-prefetched; BlockSpec index_maps use it to stream the
right expert's weight block HBM->VMEM. This replaces the CUDA kernel's
shared-memory reuse of the sorted expert matrix with Mosaic-scheduled DMA of one
(K, TN) weight tile per grid step. The plan is threaded through forward AND
backward via custom_vjp residuals, so backward never re-derives the layout.

Unfused kernels (building blocks, also the backward pass of the fused path)
  cvmm_pallas     out[t] = x[t] @ w[tile_expert[t]]        grid (m_tiles, n_tiles)
  cvmm_dw_pallas  dw[e]  = sum_{t: expert(t)=e} x[t]^T g[t] grid (k, n, m); m
                  innermost — tile_expert is non-decreasing, so output-block
                  revisits are consecutive and accumulation is legal on TPU.

Fused forward pipeline (one HBM round-trip per matmul, nothing else)
  cvmm_fused_w1_pallas   gather + GEMM + activation(/GLU) epilogue. ``row_src``
      is scalar-prefetched; on the first N-tile of each row tile the kernel
      gathers the TM source rows of the *unsorted* activations (resident in
      VMEM as a whole-array block) into a scratch tile via dynamic slices, then
      reuses the scratch for the remaining N-tiles. With GLU both W1 and W1g
      blocks are read in the same grid pass and u = act(x@w1) * (x@w1g) is
      written directly — the materialized (N*K, d) gather, the x_pad scatter,
      and the standalone activation pass all disappear.
  cvmm_fused_w2_pallas   GEMM + per-row gate multiply in the epilogue, so
      ``y_sorted * g_flat[perm]`` is never a separate XLA pass.

dX reuses the forward kernel with w transposed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import act_fn
from .compat import tpu_compiler_params

TM = 128            # row tile (MXU-aligned)
LANE = 128          # lane multiple for K / N
VMEM_BUDGET = 12 * 1024 * 1024

# Activations that are elementwise (tile-local) and therefore legal to apply
# inside a kernel epilogue on an (TM, TN) tile.
FUSIBLE_ACTIVATIONS = ("relu", "gelu", "silu", "identity")


def _pick_tn(k_pad: int, n_pad: int, bytes_per_el: int) -> int:
    """Largest N tile (multiple of 128, <= n_pad) whose working set fits VMEM."""
    for tn in (512, 384, 256, 128):
        if tn > n_pad:
            continue
        if n_pad % tn:
            continue
        ws = TM * k_pad * bytes_per_el + k_pad * tn * bytes_per_el + TM * tn * 4
        if ws <= VMEM_BUDGET:
            return tn
    return 128


def fused_w1_tn(n_rows: int, k_pad: int, g_pad: int, bytes_per_el: int,
                n_weights: int, n_out: int):
    """Largest fitting N tile for the gather-fused w1 kernel, or None.

    Unlike ``_pick_tn`` this models the kernel's FULL working set — the
    whole-array x block, the (TM, K) gather scratch, every weight tile and
    every output tile (3 with GLU + save_preact) — and returns None rather
    than silently under-tiling when nothing fits: callers must fall back to
    the unfused path instead of compiling a kernel that exhausts VMEM."""
    x_bytes = n_rows * k_pad * bytes_per_el
    scratch = TM * k_pad * bytes_per_el
    for tn in (512, 384, 256, 128):
        if tn > g_pad or g_pad % tn:
            continue
        ws = (x_bytes + scratch + n_weights * k_pad * tn * bytes_per_el
              + n_out * TM * tn * max(bytes_per_el, 4))
        if ws <= VMEM_BUDGET:
            return tn
    return None


# ---------------------------------------------------------------------------
# Forward kernel (unfused building block)
# ---------------------------------------------------------------------------

def _fwd_kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    # x_ref: (TM, K), w_ref: (1, K, TN), o_ref: (TM, TN)
    acc = jnp.dot(x_ref[...], w_ref[0],
                  preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def cvmm_pallas(x_pad: jax.Array, tile_expert: jax.Array, w: jax.Array,
                *, interpret: bool = False) -> jax.Array:
    """x_pad (M_pad, K_pad) sorted+tile-aligned rows; tile_expert (M_pad//TM,) int32;
    w (E, K_pad, N_pad). Returns (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    e, k_w, n_pad = w.shape
    assert k_w == k_pad and m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    tn = _pick_tn(k_pad, n_pad, x_pad.dtype.itemsize)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, k_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, k_pad, tn), lambda i, j, te: (te[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), x_pad.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, w)


# ---------------------------------------------------------------------------
# dW kernel (grouped outer-product accumulation)
# ---------------------------------------------------------------------------

def _dw_kernel(tile_expert_ref, x_ref, g_ref, o_ref):
    # grid (k_tiles, n_tiles, m_tiles); m innermost.
    m = pl.program_id(2)
    e_now = tile_expert_ref[m]
    e_prev = tile_expert_ref[jnp.maximum(m - 1, 0)]
    first = jnp.logical_or(m == 0, e_now != e_prev)
    acc = jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (TK, TN)

    @pl.when(first)
    def _init():
        o_ref[0] = acc

    @pl.when(jnp.logical_not(first))
    def _acc():
        o_ref[0] += acc


def cvmm_dw_pallas(x_pad: jax.Array, tile_expert: jax.Array, g_pad: jax.Array,
                   n_experts: int, *, interpret: bool = False) -> jax.Array:
    """dW (E, K_pad, N_pad) float32 from tile-aligned x (M_pad, K_pad), g (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    _, n_pad = g_pad.shape
    assert m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    tk = _pick_tn(TM, k_pad, x_pad.dtype.itemsize)
    tn = _pick_tn(TM, n_pad, g_pad.dtype.itemsize)
    grid = (k_pad // tk, n_pad // tn, m_pad // TM)

    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, tk), lambda k, n, m, te: (m, k)),
                pl.BlockSpec((TM, tn), lambda k, n, m, te: (m, n)),
            ],
            out_specs=pl.BlockSpec((1, tk, tn), lambda k, n, m, te: (te[m], k, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, k_pad, n_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, g_pad)


# ---------------------------------------------------------------------------
# Fused forward kernels
# ---------------------------------------------------------------------------

def _gather_rows(i, row_src_ref, x_ref, xs_ref, n_rows: int):
    """Gather the TM source rows of row tile ``i`` into VMEM scratch.

    Runs on the first N-tile of each row tile only; the scratch persists across
    the (sequential) inner grid dimension. Slack slots carry the sentinel
    ``n_rows`` — clamped here, their (finite) outputs are killed by the zero
    gate and the scatter-drop at the XLA level.
    """
    def body(r, _):
        src = jnp.minimum(row_src_ref[i * TM + r], n_rows - 1)
        xs_ref[pl.ds(r, 1), :] = x_ref[pl.ds(src, 1), :]
        return 0

    jax.lax.fori_loop(0, TM, body, 0)


def _fused_w1_body(row_src_ref, x_ref, w1_ref, w1g_ref, o_u_ref, o_h_ref,
                   o_hg_ref, xs_ref, *, act_name: str, n_rows: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _():
        _gather_rows(i, row_src_ref, x_ref, xs_ref, n_rows)
    h = jnp.dot(xs_ref[...], w1_ref[0], preferred_element_type=jnp.float32)
    u = act_fn(act_name)(h)
    if w1g_ref is not None:
        hg = jnp.dot(xs_ref[...], w1g_ref[0],
                     preferred_element_type=jnp.float32)
        u = u * hg
        if o_hg_ref is not None:
            o_hg_ref[...] = hg.astype(o_hg_ref.dtype)
    if o_h_ref is not None:
        o_h_ref[...] = h.astype(o_h_ref.dtype)
    o_u_ref[...] = u.astype(o_u_ref.dtype)


def _k_w1(rs, te, x, w1, o_u, xs, **kw):
    _fused_w1_body(rs, x, w1, None, o_u, None, None, xs, **kw)


def _k_w1_save(rs, te, x, w1, o_u, o_h, xs, **kw):
    _fused_w1_body(rs, x, w1, None, o_u, o_h, None, xs, **kw)


def _k_w1_glu(rs, te, x, w1, w1g, o_u, xs, **kw):
    _fused_w1_body(rs, x, w1, w1g, o_u, None, None, xs, **kw)


def _k_w1_glu_save(rs, te, x, w1, w1g, o_u, o_h, o_hg, xs, **kw):
    _fused_w1_body(rs, x, w1, w1g, o_u, o_h, o_hg, xs, **kw)


def cvmm_fused_w1_pallas(x: jax.Array, row_src: jax.Array,
                         tile_expert: jax.Array, w1: jax.Array,
                         w1g: jax.Array | None, *, act_name: str,
                         save_preact: bool = False,
                         interpret: bool = False):
    """Gather-fused grouped GEMM with activation(/GLU) epilogue.

    x (N_rows, K_pad) — the UNSORTED activations, resident in VMEM as one
    block; row_src (M_pad,) int32 maps padded slots to rows of x (sentinel
    N_rows on slack); w1/w1g (E, K_pad, G_pad). Returns u (M_pad, G_pad) in the
    tile-aligned sorted layout, already activated (and gated when w1g given).

    ``save_preact=True`` (training: the custom_vjp forward rule) additionally
    writes the pre-activations h (and hg with GLU) in the same grid pass, so
    the backward pass needs no recompute GEMMs; returns (u, h[, hg])."""
    n_rows, k_pad = x.shape
    e, k_w, g_pad = w1.shape
    m_pad = row_src.shape[0]
    assert k_w == k_pad and m_pad % TM == 0
    assert k_pad % LANE == 0 and g_pad % LANE == 0 and n_rows % 8 == 0
    n_weights = 2 if w1g is not None else 1
    n_out = (1 + n_weights) if save_preact else 1
    tn = fused_w1_tn(n_rows, k_pad, g_pad, x.dtype.itemsize, n_weights, n_out)
    if tn is None:
        raise ValueError(
            f"fused w1 working set exceeds VMEM budget for x ({n_rows}, "
            f"{k_pad}); gate calls with ops.fused_supported")
    grid = (m_pad // TM, g_pad // tn)

    w_spec = pl.BlockSpec((1, k_pad, tn), lambda i, j, rs, te: (te[i], 0, j))
    o_spec = pl.BlockSpec((TM, tn), lambda i, j, rs, te: (i, j))
    o_shape = jax.ShapeDtypeStruct((m_pad, g_pad), x.dtype)
    in_specs = [pl.BlockSpec((n_rows, k_pad), lambda i, j, rs, te: (0, 0)),
                w_spec]
    operands = [row_src, tile_expert, x, w1]
    if w1g is not None:
        in_specs.append(w_spec)
        operands.append(w1g)
        kernel = _k_w1_glu_save if save_preact else _k_w1_glu
    else:
        kernel = _k_w1_save if save_preact else _k_w1
    kernel = functools.partial(kernel, act_name=act_name, n_rows=n_rows)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=[o_spec] * n_out,
            scratch_shapes=[pltpu.VMEM((TM, k_pad), x.dtype)],
        ),
        out_shape=[o_shape] * n_out,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0] if n_out == 1 else tuple(out)


def _fused_w2_kernel(tile_expert_ref, u_ref, w2_ref, gate_ref, o_ref):
    acc = jnp.dot(u_ref[...], w2_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] = (acc * gate_ref[0][:, None]).astype(o_ref.dtype)


def cvmm_fused_w2_pallas(u_pad: jax.Array, tile_expert: jax.Array,
                         w2: jax.Array, gate_tiles: jax.Array,
                         *, interpret: bool = False) -> jax.Array:
    """Grouped GEMM with the per-row gate multiply fused into the epilogue.

    u_pad (M_pad, G_pad) tile-aligned; w2 (E, G_pad, N_pad);
    gate_tiles (M_pad//TM, TM) float32. Returns (M_pad, N_pad)."""
    m_pad, g_pad = u_pad.shape
    e, g_w, n_pad = w2.shape
    assert g_w == g_pad and m_pad % TM == 0
    assert g_pad % LANE == 0 and n_pad % LANE == 0
    assert gate_tiles.shape == (m_pad // TM, TM)
    tn = _pick_tn(g_pad, n_pad, u_pad.dtype.itemsize)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fused_w2_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, g_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, g_pad, tn), lambda i, j, te: (te[i], 0, j)),
                pl.BlockSpec((1, TM), lambda i, j, te: (i, 0)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), u_pad.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, u_pad, w2, gate_tiles)
