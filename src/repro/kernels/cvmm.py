"""Pallas TPU kernels for CVMM — conditional (grouped) matmul, the paper's CUDA
kernel adapted to the TPU memory hierarchy (DESIGN.md Sec. 4).

Layout contract (established by ops.py, shared by every kernel here)
--------------------------------------------------------------------
Rows are sorted by expert and each expert's row-range is padded to a multiple of
the row tile TM, so **every (TM, K) row tile belongs to exactly one expert**.
ops.py computes this layout ONCE per MoE call into a ``CvmmPlan``:

  ``new_pos``     (M,)        tile-aligned slot of sorted row i
  ``row_src``     (M_pad,)    source row in the *unsorted* activations for each
                              padded slot; slack slots hold the sentinel N (one
                              past the last row) so XLA-side scatters drop them
  ``run_start``   (M_pad,)    per-tile DMA chunk table: in-tile slot where
  ``run_len``     (M_pad,)    chunk j of tile t starts, and its length (0 =
                              unused entry); see ops._plan_runs
  ``run_off``     (M_pad/TM*9,) per-tile size-class boundaries into that
                              table (chunks are grouped largest-class first)
  ``tile_expert`` (M_pad/TM,) row-tile index -> expert id (non-decreasing)
  ``gate_tiles``  (M_pad/TM, TM) float32 gate per padded slot, 0 on slack

``tile_expert`` is scalar-prefetched; BlockSpec index_maps use it to stream the
right expert's weight block HBM->VMEM. This replaces the CUDA kernel's
shared-memory reuse of the sorted expert matrix with Mosaic-scheduled DMA of one
(K, TN) weight tile per grid step. The plan is threaded through forward AND
backward via custom_vjp residuals, so backward never re-derives the layout.

Unfused kernels (building blocks, also the backward pass of the unfused path)
  cvmm_pallas     out[t] = x[t] @ w[tile_expert[t]]        grid (m_tiles, n_tiles)
  cvmm_dw_pallas  dw[e]  = sum_{t: expert(t)=e} x[t]^T g[t] grid (k, n, m); m
                  innermost — tile_expert is non-decreasing, so output-block
                  revisits are consecutive and accumulation is legal on TPU.

Run-batched row-DMA pipeline (shared by every streamed kernel below)
  ``row_src`` alone would force one ``make_async_copy`` per row (TM
  descriptors per tile). The plan therefore carries a per-tile chunk table
  (``run_start``/``run_len``/``run_off``, built by ops._plan_runs): maximal
  contiguous ``row_src`` runs, greedily decomposed into power-of-two chunks
  because DMA copy shapes must be static. Chunks are grouped by size class
  (``run_off`` boundaries), so ``_gather_issue`` runs one dynamic-bound loop
  per static class in ``_RUN_SIZES`` and issues ONE copy per chunk — no
  per-entry size dispatch, and total loop iterations == #chunks. A fully
  contiguous tile (K=1, skewed routing) is 1 descriptor instead of 128; the
  worst case (no two sources adjacent) degrades to the old per-row count.
  Slack slots belong to no chunk and keep the zero fill before the DMAs.

Fused/streamed pipeline (one HBM round-trip per matmul, nothing else)
  cvmm_fused_w1_pallas   gather + GEMM + activation(/GLU) epilogue. The
      unsorted activations stay in HBM (``pltpu.ANY`` memory space) — the
      kernel never requires whole-array VMEM residency, so it scales to
      production token counts. The chunk table is scalar-prefetched and
      drives a double-buffered DMA pipeline: on the first N-tile of row tile
      ``i`` the kernel waits for tile ``i``'s gather (issued one tile earlier
      into one of two (TM, K) VMEM scratch buffers) and immediately starts
      tile ``i+1``'s gather into the other buffer, so the HBM reads overlap
      the MXU work of the current tile. Slack outputs are finite (zero-filled
      scratch) and killed downstream by the zero gate + scatter-drop. With
      GLU both W1 and W1g blocks are read in the same grid pass and
      u = act(x@w1) * (x@w1g) is written directly. The backward pass reuses
      this kernel with ``act_name="identity"`` for t0 = gather(dy) @ w2^T —
      the cotangent rows also stream straight out of HBM.
  cvmm_fused_w2_pallas   GEMM + per-row gate multiply in the epilogue, so
      ``y_sorted * g_flat[perm]`` is never a separate XLA pass.
  cvmm_dw_streamed_pallas  dw[e] = sum x^T g with ONE operand streamed from
      the unsorted HBM array through the same pipeline (grid (n, m), m
      innermost; the stream restarts per n-pass). Backward's dW1/dW1g stream
      the activations; dW2 streams the cotangent and fuses the ``dy * gate``
      multiply into the epilogue — no tile-aligned (M_pad, K) gather copy of
      either operand is ever materialized in HBM.
  cvmm_gather_rows_pallas  the pipeline as a bare gather: unsorted HBM rows
      -> tile-aligned (M_pad, K) layout, zeros on slack. No longer on the
      MoE training path (backward streams instead), but — with the optional
      ``weight_tiles`` epilogue (per-row multiply in VMEM) — it is the
      execution kernel of the framework's weighted value aggregation.
      The production caller is ``ops.gathered_weighted_sum_dedup``
      (``DedupGatherPlan``): ``row_src`` there is the batch's DEDUPLICATED,
      value-index-SORTED selection union — ascending row ids, sentinel
      slack at the tail — so co-selected value rows cost one DMA total and
      adjacent indices form real contiguous runs for the chunk table to
      pack into multi-row descriptors (hot PKM values: whole size-32/64
      chunks instead of 128 singles). The kernel itself is layout-agnostic:
      it just executes whatever chunk table ops._plan_runs derived, which
      is why the flat per-selection ``GatherPlan``
      (ops.gathered_weighted_sum, kept for tests/telemetry) runs through
      the same code. PKM value lookup and the top-K MLP's sparse
      down-projection lower here via dispatch.weighted_value_sum, so the
      value table never needs whole-array residency and no (N, S, d) dense
      gather is materialized at the XLA level.

VMEM working set per grid step: two (TM, K) gather buffers + the (pipelined)
weight/operand and output tiles — independent of the activation row count
(``fused_w1_tn`` / ``streamed_dw_tile`` do the accounting; ``ops.fused_supported``
gates on this tile-level residency only, forward AND backward kernels).

dX on tile-aligned operands reuses cvmm_pallas with w transposed.

Tuning
------
Tile choices come from kernels/autotune.py. Every picker below (``_pick_tn``,
``fused_w1_tn``, ``streamed_dw_tile``, ``gather_tile_fits``) is a thin query
into the tuner, threading this module's ``VMEM_BUDGET`` (itself derived from
the active ``roofline.analysis.Hardware`` model — tests monkeypatch the
module attribute to shrink every picker at once). With tuning DISABLED (the
default, and what interpret-mode CI runs) the tuner answers with the static
heuristic — the largest LANE multiple dividing the padded width whose working
set fits — at zero cost, no I/O. With tuning ENABLED (``REPRO_AUTOTUNE=1`` /
``benchmarks.run --tune``) candidates are roofline-pruned and micro-benchmarked
once per (kernel, shape-class, dtype, backend) key, and winners persist to
``~/.cache/repro/autotune/<backend>.json`` (override the directory with
``REPRO_AUTOTUNE_CACHE``). Pre-warm a new backend with::

    python -m benchmarks.run --quick --tune

Interpret-mode timings only rank candidates relative to each other on the
interpreter's cost surface — they are NOT TPU numbers; the on-disk cache is
keyed per backend precisely so a CPU-tuned cache never leaks into TPU runs.
Every kernel entry point also accepts explicit tile arguments (``tn`` / ``tb``
/ ``n_buffers``) so ops.py can resolve tiles once per plan and thread them
through forward and backward instead of re-querying per call.

Static checks
-------------
The pipeline contract above is not prose-only: ``stream_schedule_step`` is the
executable source of truth for the issue/wait schedule, and
``repro.analysis.pipeline`` replays it over concrete grids at every supported
depth, proving issue/wait pairing per slot, no overwrite of an in-flight slot,
and clean warmup/drain (including ``n_tiles < n_buffers`` and the dW kernels'
per-pass re-entry). ``python -m repro.analysis.check --all`` runs that proof
plus the plan-invariant, VMEM-budget, and sharding-table passes; CI gates on
it. When changing the schedule, the chunk-table layout, or a working-set
formula, run the checker first — it fails faster than a miscompiled kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import act_fn
from . import autotune
from .autotune import LANE, TM
from .compat import tpu_compiler_params

# Per-kernel VMEM working-set budget. Derived from the active Hardware model
# (0.75 * vmem_bytes = 12 MiB on the TPU model; $REPRO_VMEM_BUDGET overrides)
# and read at CALL time by every picker below, so tests that monkeypatch this
# module attribute shrink all residency gates at once.
VMEM_BUDGET = autotune.default_vmem_budget()
N_BUFFERS = 2       # default gather scratch slots (double buffering); the
                    # tuner may thread a deeper pipeline into any streamed call

# Activations that are elementwise (tile-local) and therefore legal to apply
# inside a kernel epilogue on an (TM, TN) tile.
FUSIBLE_ACTIVATIONS = ("relu", "gelu", "silu", "identity")


def _pick_tn(k_pad: int, n_pad: int, bytes_per_el: int):
    """Largest N tile (LANE multiple dividing n_pad) whose working set fits
    VMEM, or None when even tn=128 does not fit — same contract as
    ``fused_w1_tn``: callers raise (or gate via ``ops.fused_supported``)
    instead of compiling a kernel that exhausts VMEM. Thin query into the
    tuner (kernels/autotune.py): this replaces the old fixed (512, 384, 256,
    128) ladder, whose divisibility check skipped every larger legal tile for
    widths like n_pad=640 that are multiples of 128 but of neither 384 nor
    512."""
    return autotune.pick_tn(k_pad, n_pad, bytes_per_el, budget=VMEM_BUDGET)


def _require_tn(tn, kernel: str, k_pad: int):
    if tn is None:
        raise ValueError(
            f"{kernel}: no N tile fits the VMEM budget for K_pad={k_pad}; "
            f"gate calls with ops.fused_supported or use an unfused impl")
    return tn


def fused_w1_tn(k_pad: int, g_pad: int, bytes_per_el: int,
                n_weights: int, n_out: int):
    """Largest fitting N tile for the streamed gather-fused w1 kernel, or None.

    Models the kernel's FULL per-step working set — two (TM, K) gather scratch
    buffers, plus the weight tiles and output tiles (3 with GLU + save_preact)
    at 2x for Mosaic's automatic pipeline double-buffering of blocked operands.
    The activations stream row-by-row from HBM, so — unlike the retired
    whole-x-resident kernel — the row count does not appear here at all.
    Returns None rather than silently under-tiling when nothing fits: callers
    must fall back to the unfused path instead of compiling a kernel that
    exhausts VMEM. Thin query into the tuner (the working-set formula lives in
    ``autotune.ws_fused_w1``); the full decision — including pipeline depth —
    is ``autotune.fused_w1_tiles``, which ops.py threads through the plan."""
    d = autotune.fused_w1_tiles(k_pad, g_pad, bytes_per_el, n_weights, n_out,
                                budget=VMEM_BUDGET)
    return None if d.tiles is None else d.tiles["tn"]


def streamed_dw_tile(stream_w_pad: int, block_w_pad: int, bytes_per_el: int):
    """Largest tile over the BLOCKED operand's width for the streamed dW
    kernel, or None when nothing fits.

    Working set: two (TM, W_stream) gather scratch buffers, plus the blocked
    (TM, t) operand tile and the (W_stream, t) float32 output block at 2x for
    Mosaic's pipeline double-buffering. As with ``fused_w1_tn``, the streamed
    operand's row count never appears — it lives in HBM. Thin query into the
    tuner (formula: ``autotune.ws_streamed_dw``)."""
    d = autotune.streamed_dw_tiles(stream_w_pad, block_w_pad, bytes_per_el,
                                   budget=VMEM_BUDGET)
    return None if d.tiles is None else d.tiles["tb"]


def legacy_whole_x_rows(k_pad: int, bytes_per_el: int, n_weights: int,
                        n_out: int) -> int:
    """Max activation rows the RETIRED whole-x-resident w1 kernel accepted.

    The pre-streaming kernel kept the entire (N, K) unsorted activation block
    in VMEM next to one (TM, K) gather scratch, the weight tiles and the output
    tiles (at the minimum tn=128), so its residency gate capped the row count
    at roughly (VMEM_BUDGET - tiles) / row_bytes. Kept as the reference point
    for tests and benchmarks that must demonstrate the streamed kernel working
    far beyond this boundary; reads ``VMEM_BUDGET`` at call time so tests can
    shrink the budget to sweep the boundary cheaply."""
    tiles = (TM * k_pad * bytes_per_el
             + n_weights * k_pad * 128 * bytes_per_el
             + n_out * TM * 128 * max(bytes_per_el, 4))
    return max((VMEM_BUDGET - tiles) // (k_pad * bytes_per_el), 0)


# ---------------------------------------------------------------------------
# Forward kernel (unfused building block)
# ---------------------------------------------------------------------------

def _fwd_kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    # x_ref: (TM, K), w_ref: (1, K, TN), o_ref: (TM, TN)
    acc = jnp.dot(x_ref[...], w_ref[0],
                  preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def cvmm_pallas(x_pad: jax.Array, tile_expert: jax.Array, w: jax.Array,
                *, interpret: bool = False,
                tn: int | None = None) -> jax.Array:
    """x_pad (M_pad, K_pad) sorted+tile-aligned rows; tile_expert (M_pad//TM,) int32;
    w (E, K_pad, N_pad). Returns (M_pad, N_pad). ``tn`` threads a pre-resolved
    tile choice (ops.py / the tuner); omitted -> heuristic query."""
    m_pad, k_pad = x_pad.shape
    e, k_w, n_pad = w.shape
    assert k_w == k_pad and m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    if tn is None:
        tn = _pick_tn(k_pad, n_pad, x_pad.dtype.itemsize)
    tn = _require_tn(tn, "cvmm_pallas", k_pad)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, k_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, k_pad, tn), lambda i, j, te: (te[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), x_pad.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, w)


# ---------------------------------------------------------------------------
# dW kernel (grouped outer-product accumulation)
# ---------------------------------------------------------------------------

def _dw_kernel(tile_expert_ref, x_ref, g_ref, o_ref):
    # grid (k_tiles, n_tiles, m_tiles); m innermost.
    m = pl.program_id(2)
    e_now = tile_expert_ref[m]
    e_prev = tile_expert_ref[jnp.maximum(m - 1, 0)]
    first = jnp.logical_or(m == 0, e_now != e_prev)
    acc = jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (TK, TN)

    @pl.when(first)
    def _init():
        o_ref[0] = acc

    @pl.when(jnp.logical_not(first))
    def _acc():
        o_ref[0] += acc


def cvmm_dw_pallas(x_pad: jax.Array, tile_expert: jax.Array, g_pad: jax.Array,
                   n_experts: int, *, interpret: bool = False,
                   tk: int | None = None, tn: int | None = None) -> jax.Array:
    """dW (E, K_pad, N_pad) float32 from tile-aligned x (M_pad, K_pad), g (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    _, n_pad = g_pad.shape
    assert m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    if tk is None:
        tk = _pick_tn(TM, k_pad, x_pad.dtype.itemsize)
    if tn is None:
        tn = _pick_tn(TM, n_pad, g_pad.dtype.itemsize)
    tk = _require_tn(tk, "cvmm_dw_pallas", TM)
    tn = _require_tn(tn, "cvmm_dw_pallas", TM)
    grid = (k_pad // tk, n_pad // tn, m_pad // TM)

    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, tk), lambda k, n, m, te: (m, k)),
                pl.BlockSpec((TM, tn), lambda k, n, m, te: (m, n)),
            ],
            out_specs=pl.BlockSpec((1, tk, tn), lambda k, n, m, te: (te[m], k, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, k_pad, n_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, g_pad)


# ---------------------------------------------------------------------------
# Fused forward kernels
# ---------------------------------------------------------------------------

# Static DMA chunk sizes (copy shapes cannot be dynamic): the greedy
# power-of-two decomposition of a maximal contiguous row_src run, largest
# first. A full tile is one size-TM descriptor; isolated rows are size 1.
_RUN_SIZES = tuple(1 << b for b in range(TM.bit_length() - 1, -1, -1))

# The streamed-pipeline users of stream_schedule_step, in the analyzer's terms:
# how many sequential grid passes walk the row tiles per launch. The dW kernels
# re-enter the stream at i == 0 once per outer (blocked-width) pass; the fused
# w1 kernel steps the stream only on the first N-tile of each row tile, so it
# behaves as the single-pass gather. repro.analysis.pipeline replays every
# entry here at every supported depth.
STREAMED_PIPELINES = {
    "fused_w1": dict(reentrant=False),     # grid (m, n); stream stepped at j==0
    "gather": dict(reentrant=False),       # grid (m,)
    "dw_streamed": dict(reentrant=True),   # grid (b, m), m innermost; the
                                           # stream restarts on every b pass
}


def stream_slot(t, n_buffers: int):
    """Scratch slot holding row tile ``t`` at pipeline depth ``n_buffers``.

    Pure arithmetic shared by the kernels (traced ``t``) and the static hazard
    checker in ``repro.analysis.pipeline`` (concrete ``t``)."""
    return t % n_buffers


def stream_schedule_step(i, m_tiles: int, n_buffers: int, *, issue, wait,
                         when):
    """Control skeleton of the streamed gather pipeline at row tile ``i`` —
    THE source of truth for the issue/wait schedule.

    The Pallas kernels execute it with real DMA callbacks and a traced ``i``
    (``when`` is ``pl.when``); the static hazard checker
    (``repro.analysis.pipeline``) replays it with recording callbacks over
    concrete grids and proves issue/wait pairing, no slot overwrite before its
    wait, and clean warmup/drain — including ``m_tiles < n_buffers`` — at
    every supported depth. Editing the schedule here changes the kernels AND
    what the analyzer verifies; the seeded-mutant tests rely on that.

    Schedule: warm-up at i == 0 issues tiles 0..n_buffers-2 (statically
    unrolled; guarded so a short grid never touches a missing tile's chunk
    table), every step waits for tile ``i`` (issued n_buffers-1 steps
    earlier), then prefetches tile ``i + n_buffers - 1`` into the slot that
    just freed — suppressed past the last tile so no DMA is left in flight at
    the end of a pass. Returns the slot holding tile ``i``."""
    when(i == 0, lambda: issue(0))
    for t in range(1, n_buffers - 1):
        when((i == 0) & (t < m_tiles), lambda t=t: issue(t))
    wait(i)
    when(i + n_buffers - 1 < m_tiles, lambda: issue(i + n_buffers - 1))
    return stream_slot(i, n_buffers)


def _run_dmas(t, row_src_ref, run_start_ref, run_off_ref, x_hbm, xs_ref,
              sem_ref, slot, *, wait: bool):
    """Issue (or wait for) the run-batched DMA chunks of row tile ``t``.

    The plan's chunk table (ops._plan_runs) batches each maximal contiguous
    ``row_src`` run into power-of-two chunks (DMA copy shapes must be
    static): ``run_start[t*TM + j]`` is chunk j's in-tile destination slot,
    and the chunks are grouped by size class with per-tile boundaries in
    ``run_off`` — class ci's chunks occupy entries [run_off[t*9+ci],
    run_off[t*9+ci+1]). The kernel therefore runs one dynamic-bound loop per
    STATIC size class and issues ONE ``make_async_copy`` per chunk, with no
    per-entry size dispatch: total loop iterations == #chunks, versus one
    copy (and one predicate) per row before run batching. Slack slots are
    covered by no chunk and keep the zeros written by ``_gather_issue``. All
    chunks of a tile signal the slot's semaphore; the wait pass reconstructs
    identical descriptors."""
    cbase = t * (len(_RUN_SIZES) + 1)
    for ci, s in enumerate(_RUN_SIZES):
        # A chunk spans s consecutive SOURCE rows, so classes larger than the
        # HBM operand's row count can never occur — skipping them keeps every
        # traced slice shape legal against the operand.
        if s > x_hbm.shape[0]:
            continue

        def body(j, _, s=s):
            off = run_start_ref[t * TM + j]
            src = row_src_ref[t * TM + off]
            cp = pltpu.make_async_copy(x_hbm.at[pl.ds(src, s), :],
                                       xs_ref.at[slot, pl.ds(off, s), :],
                                       sem_ref.at[slot])
            cp.wait() if wait else cp.start()
            return 0

        jax.lax.fori_loop(run_off_ref[cbase + ci], run_off_ref[cbase + ci + 1],
                          body, 0)


def _gather_issue(t, row_src_ref, run_start_ref, run_off_ref, x_hbm, xs_ref,
                  sem_ref, n_buffers: int = N_BUFFERS):
    """Zero slot ``t % n_buffers`` and start the run-batched DMAs of tile ``t``."""
    slot = stream_slot(t, n_buffers)
    xs_ref[slot] = jnp.zeros(xs_ref.shape[1:], xs_ref.dtype)
    _run_dmas(t, row_src_ref, run_start_ref, run_off_ref, x_hbm, xs_ref,
              sem_ref, slot, wait=False)


def _gather_wait(t, row_src_ref, run_start_ref, run_off_ref, x_hbm, xs_ref,
                 sem_ref, n_buffers: int = N_BUFFERS):
    """Wait for every DMA chunk issued by ``_gather_issue`` for tile ``t``."""
    slot = stream_slot(t, n_buffers)
    _run_dmas(t, row_src_ref, run_start_ref, run_off_ref, x_hbm, xs_ref,
              sem_ref, slot, wait=True)


def _stream_tile(i, row_src_ref, run_start_ref, run_off_ref, x_hbm, xs_ref,
                 sem_ref, *, axis: int = 0, n_buffers: int = N_BUFFERS):
    """Pipelined gather step for row tile ``i`` (grid dim ``axis``, sequential
    and innermost), ``n_buffers`` scratch slots deep.

    Waits for tile ``i``'s chunks (issued ``n_buffers - 1`` tiles earlier;
    warm-up issues tiles 0..n_buffers-2 inline) and immediately starts tile
    ``i + n_buffers - 1``'s DMAs into the slot that just freed, so the HBM
    reads of upcoming tiles overlap this tile's MXU work. Returns the slot
    holding tile ``i``. With the default depth 2 this is exactly the classic
    double buffer: warm-up issues tile 0, each step prefetches tile i+1.
    Kernels whose row-tile loop is an inner grid dimension (the streamed dW
    kernels) re-enter at i == 0 once per outer pass: the warm-up re-issues its
    tiles and prefetches past the last tile are suppressed, so no DMA is left
    in flight across pass boundaries.

    The actual issue/wait ordering lives in ``stream_schedule_step`` (shared
    with the static hazard checker); this wrapper only binds the DMA
    callbacks."""
    m_tiles = pl.num_programs(axis)

    def issue(t):
        _gather_issue(t, row_src_ref, run_start_ref, run_off_ref, x_hbm,
                      xs_ref, sem_ref, n_buffers)

    def wait(t):
        _gather_wait(t, row_src_ref, run_start_ref, run_off_ref, x_hbm,
                     xs_ref, sem_ref, n_buffers)

    return stream_schedule_step(i, m_tiles, n_buffers, issue=issue, wait=wait,
                                when=lambda cond, fn: pl.when(cond)(fn))


def _fused_w1_body(row_src_ref, run_start_ref, run_off_ref, x_hbm, w1_ref,
                   w1g_ref, o_u_ref, o_h_ref, o_hg_ref, xs_ref, sem_ref,
                   *, act_name: str, n_buffers: int = N_BUFFERS):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _():
        _stream_tile(i, row_src_ref, run_start_ref, run_off_ref, x_hbm,
                     xs_ref, sem_ref, n_buffers=n_buffers)
    xt = xs_ref[stream_slot(i, n_buffers)]
    h = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    u = act_fn(act_name)(h)
    if w1g_ref is not None:
        hg = jnp.dot(xt, w1g_ref[0],
                     preferred_element_type=jnp.float32)
        u = u * hg
        if o_hg_ref is not None:
            o_hg_ref[...] = hg.astype(o_hg_ref.dtype)
    if o_h_ref is not None:
        o_h_ref[...] = h.astype(o_h_ref.dtype)
    o_u_ref[...] = u.astype(o_u_ref.dtype)


def _k_w1(rs, rst, rl, te, x, w1, o_u, xs, sem, **kw):
    _fused_w1_body(rs, rst, rl, x, w1, None, o_u, None, None, xs, sem, **kw)


def _k_w1_save(rs, rst, rl, te, x, w1, o_u, o_h, xs, sem, **kw):
    _fused_w1_body(rs, rst, rl, x, w1, None, o_u, o_h, None, xs, sem, **kw)


def _k_w1_glu(rs, rst, rl, te, x, w1, w1g, o_u, xs, sem, **kw):
    _fused_w1_body(rs, rst, rl, x, w1, w1g, o_u, None, None, xs, sem, **kw)


def _k_w1_glu_save(rs, rst, rl, te, x, w1, w1g, o_u, o_h, o_hg, xs, sem, **kw):
    _fused_w1_body(rs, rst, rl, x, w1, w1g, o_u, o_h, o_hg, xs, sem, **kw)


def cvmm_fused_w1_pallas(x: jax.Array, row_src: jax.Array,
                         run_start: jax.Array, run_off: jax.Array,
                         tile_expert: jax.Array, w1: jax.Array,
                         w1g: jax.Array | None, *, act_name: str,
                         save_preact: bool = False,
                         interpret: bool = False,
                         tn: int | None = None,
                         n_buffers: int | None = None):
    """Streamed gather-fused grouped GEMM with activation(/GLU) epilogue.

    x (N_rows, K_pad) — the UNSORTED activations, left in HBM (``pltpu.ANY``)
    and streamed through the run-batched double-buffered async-copy pipeline
    (see ``_stream_tile``); the row count is unconstrained — no multiple-of-8
    padding, no whole-array VMEM residency. row_src (M_pad,) int32 maps padded
    slots to rows of x (sentinel >= N_rows on slack; those rows get no DMA and
    stay zero-filled); run_start (M_pad,) / run_off (M_pad//TM*9,) int32 are
    the per-tile DMA chunk table (ops._plan_runs); w1/w1g (E, K_pad, G_pad).
    Returns u
    (M_pad, G_pad) in the tile-aligned sorted layout, already activated (and
    gated when w1g given). The backward pass reuses this kernel with
    ``act_name="identity"`` to stream-gather ∘ GEMM the incoming cotangent.

    ``save_preact=True`` (training: the custom_vjp forward rule) additionally
    writes the pre-activations h (and hg with GLU) in the same grid pass, so
    the backward pass needs no recompute GEMMs; returns (u, h[, hg]).

    ``tn`` / ``n_buffers`` (the N-tile width and gather pipeline depth) are
    normally resolved once per plan by ops.py via the tuner and threaded in;
    when omitted the kernel falls back to the heuristic query itself."""
    n_rows, k_pad = x.shape
    e, k_w, g_pad = w1.shape
    m_pad = row_src.shape[0]
    assert k_w == k_pad and m_pad % TM == 0
    assert k_pad % LANE == 0 and g_pad % LANE == 0
    assert run_start.shape == (m_pad,)
    assert run_off.shape == ((m_pad // TM) * (len(_RUN_SIZES) + 1),)
    n_weights = 2 if w1g is not None else 1
    n_out = (1 + n_weights) if save_preact else 1
    if tn is None:
        tn = fused_w1_tn(k_pad, g_pad, x.dtype.itemsize, n_weights, n_out)
    if tn is None:
        raise ValueError(
            f"fused w1 tile working set exceeds VMEM budget for K_pad="
            f"{k_pad}; gate calls with ops.fused_supported")
    n_buffers = N_BUFFERS if n_buffers is None else n_buffers
    grid = (m_pad // TM, g_pad // tn)

    w_spec = pl.BlockSpec((1, k_pad, tn),
                          lambda i, j, rs, rst, rl, te: (te[i], 0, j))
    o_spec = pl.BlockSpec((TM, tn), lambda i, j, rs, rst, rl, te: (i, j))
    o_shape = jax.ShapeDtypeStruct((m_pad, g_pad), x.dtype)
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY), w_spec]
    operands = [row_src, run_start, run_off, tile_expert, x, w1]
    if w1g is not None:
        in_specs.append(w_spec)
        operands.append(w1g)
        kernel = _k_w1_glu_save if save_preact else _k_w1_glu
    else:
        kernel = _k_w1_save if save_preact else _k_w1
    kernel = functools.partial(kernel, act_name=act_name, n_buffers=n_buffers)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=in_specs,
            out_specs=[o_spec] * n_out,
            scratch_shapes=[pltpu.VMEM((n_buffers, TM, k_pad), x.dtype),
                            pltpu.SemaphoreType.DMA((n_buffers,))],
        ),
        out_shape=[o_shape] * n_out,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0] if n_out == 1 else tuple(out)


def _gather_rows_kernel(row_src_ref, run_start_ref, run_off_ref, x_hbm, o_ref,
                        xs_ref, sem_ref, *, n_buffers: int = N_BUFFERS):
    i = pl.program_id(0)
    slot = _stream_tile(i, row_src_ref, run_start_ref, run_off_ref, x_hbm,
                        xs_ref, sem_ref, n_buffers=n_buffers)
    o_ref[...] = xs_ref[slot]


def _gather_rows_weighted_kernel(row_src_ref, run_start_ref, run_off_ref,
                                 x_hbm, w_ref, o_ref, xs_ref, sem_ref,
                                 *, n_buffers: int = N_BUFFERS):
    i = pl.program_id(0)
    slot = _stream_tile(i, row_src_ref, run_start_ref, run_off_ref, x_hbm,
                        xs_ref, sem_ref, n_buffers=n_buffers)
    o_ref[...] = (xs_ref[slot].astype(jnp.float32)
                  * w_ref[0][:, None]).astype(o_ref.dtype)


def gather_tile_fits(k_pad: int, bytes_per_el: int,
                     n_buffers: int = N_BUFFERS) -> bool:
    """Residency gate for the streamed gather kernel's per-step working set:
    ``n_buffers`` (TM, K) scratch buffers plus the blocked output tile at 2x
    for Mosaic's pipeline double-buffering. As everywhere in the streamed
    family, the HBM operand's row count never appears — it is not
    VMEM-resident. Thin query into the tuner (``autotune.ws_gather``)."""
    return autotune.gather_fits(k_pad, bytes_per_el, n_buffers,
                                budget=VMEM_BUDGET)


def cvmm_gather_rows_pallas(x: jax.Array, row_src: jax.Array,
                            run_start: jax.Array, run_off: jax.Array,
                            weight_tiles: jax.Array | None = None,
                            *, interpret: bool = False,
                            n_buffers: int | None = None) -> jax.Array:
    """Streamed gather: unsorted HBM rows -> tile-aligned (M_pad, K_pad) copy.

    The same run-batched double-buffered DMA pipeline as the fused w1 kernel,
    with the scratch tile written straight to the blocked output (slack slots
    zero). ``weight_tiles`` (M_pad//TM, TM) float32, if given, scales each
    gathered row in the epilogue — the fused lowering of the framework's
    weighted value aggregation (PKM values, top-K W2 rows): the per-row
    weight multiply never becomes a separate XLA pass, and slack rows stay
    exactly zero (zero-filled scratch times the plan's zero weight). No
    longer called by the fused MoE backward — dW/dX stream their operands in
    place — but the bare form remains the pipeline's direct test surface."""
    n_rows, k_pad = x.shape
    m_pad = row_src.shape[0]
    assert m_pad % TM == 0 and k_pad % LANE == 0
    n_buffers = N_BUFFERS if n_buffers is None else n_buffers
    if not gather_tile_fits(k_pad, x.dtype.itemsize, n_buffers):
        raise ValueError(
            f"streamed gather tile working set exceeds VMEM budget for "
            f"K_pad={k_pad}; gate calls with ops.gather_supported")
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [row_src, run_start, run_off, x]
    if weight_tiles is None:
        kernel = _gather_rows_kernel
        out_spec = pl.BlockSpec((TM, k_pad), lambda i, rs, rst, rl: (i, 0))
    else:
        assert weight_tiles.shape == (m_pad // TM, TM)
        kernel = _gather_rows_weighted_kernel
        in_specs.append(pl.BlockSpec((1, TM), lambda i, rs, rst, rl: (i, 0)))
        operands.append(weight_tiles)
        out_spec = pl.BlockSpec((TM, k_pad), lambda i, rs, rst, rl: (i, 0))
    return pl.pallas_call(
        functools.partial(kernel, n_buffers=n_buffers),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(m_pad // TM,),
            in_specs=in_specs,
            out_specs=out_spec,
            scratch_shapes=[pltpu.VMEM((n_buffers, TM, k_pad), x.dtype),
                            pltpu.SemaphoreType.DMA((n_buffers,))],
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Streamed dW kernels (backward: no tile-aligned gather ever hits HBM)
# ---------------------------------------------------------------------------

def _dw_first(te_ref, m):
    e_now = te_ref[m]
    e_prev = te_ref[jnp.maximum(m - 1, 0)]
    return jnp.logical_or(m == 0, e_now != e_prev)


def _dw_accumulate(o_ref, acc, first):
    @pl.when(first)
    def _init():
        o_ref[0] = acc

    @pl.when(jnp.logical_not(first))
    def _acc():
        o_ref[0] += acc


def _dw_stream_x_kernel(rs, rst, rl, te, x_hbm, g_ref, o_ref, xs_ref, sem_ref,
                        *, n_buffers: int = N_BUFFERS):
    # grid (n_tiles, m_tiles), m innermost; the stream restarts per n pass.
    m = pl.program_id(1)
    slot = _stream_tile(m, rs, rst, rl, x_hbm, xs_ref, sem_ref, axis=1,
                        n_buffers=n_buffers)
    acc = jax.lax.dot_general(xs_ref[slot], g_ref[...],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (K, tb)
    _dw_accumulate(o_ref, acc, _dw_first(te, m))


def _dw_stream_g_body(rs, rst, rl, g_hbm, x_ref, gate_ref, o_ref, gs_ref,
                      sem_ref, te, n_buffers: int = N_BUFFERS):
    m = pl.program_id(1)
    slot = _stream_tile(m, rs, rst, rl, g_hbm, gs_ref, sem_ref, axis=1,
                        n_buffers=n_buffers)
    gt = gs_ref[slot]
    if gate_ref is not None:
        gt = (gt.astype(jnp.float32) * gate_ref[0][:, None]).astype(gt.dtype)
    acc = jax.lax.dot_general(x_ref[...], gt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (tb, N)
    _dw_accumulate(o_ref, acc, _dw_first(te, m))


def _dw_stream_g_kernel(rs, rst, rl, te, g_hbm, x_ref, o_ref, gs_ref, sem_ref,
                        *, n_buffers: int = N_BUFFERS):
    _dw_stream_g_body(rs, rst, rl, g_hbm, x_ref, None, o_ref, gs_ref, sem_ref,
                      te, n_buffers)


def _dw_stream_g_gate_kernel(rs, rst, rl, te, g_hbm, x_ref, gate_ref, o_ref,
                             gs_ref, sem_ref, *, n_buffers: int = N_BUFFERS):
    _dw_stream_g_body(rs, rst, rl, g_hbm, x_ref, gate_ref, o_ref, gs_ref,
                      sem_ref, te, n_buffers)


def cvmm_dw_streamed_pallas(x: jax.Array, g: jax.Array, row_src: jax.Array,
                            run_start: jax.Array, run_off: jax.Array,
                            tile_expert: jax.Array, n_experts: int, *,
                            stream_x: bool,
                            gate_tiles: jax.Array | None = None,
                            interpret: bool = False,
                            tb: int | None = None,
                            n_buffers: int | None = None) -> jax.Array:
    """dW (E, K_pad, N_pad) float32 with ONE operand streamed from unsorted HBM.

    stream_x=True : ``x`` is the UNSORTED (N_rows, K_pad) activations, left in
        HBM (``pltpu.ANY``) and gathered tile-by-tile through the run-batched
        DMA pipeline; ``g`` (M_pad, N_pad) is tile-aligned and blocked
        normally. (Backward's dW1/dW1g: activations never re-materialize.)
    stream_x=False: ``g`` is the UNSORTED (N_rows, N_pad) cotangent in HBM;
        ``x`` (M_pad, K_pad) is tile-aligned. ``gate_tiles`` (M_pad//TM, TM)
        float32, if given, scales the streamed rows before the outer product —
        backward's dW2 fuses the ``dy * gate`` multiply here instead of
        materializing a gated copy. Slack slots stream as zeros either way.

    Grid (blocked_w // tb, m_tiles) with the row-tile loop innermost:
    ``tile_expert`` is non-decreasing, so output-block revisits stay
    consecutive and accumulation is legal; the gather stream restarts on each
    outer pass (the scratch only ever holds two row tiles)."""
    assert gate_tiles is None or not stream_x
    m_pad = row_src.shape[0]
    if stream_x:
        n_rows, k_pad = x.shape
        mp_g, n_pad = g.shape
        stream_w, block_w, sdtype = k_pad, n_pad, x.dtype
        assert mp_g == m_pad
    else:
        mp_x, k_pad = x.shape
        n_rows, n_pad = g.shape
        stream_w, block_w, sdtype = n_pad, k_pad, g.dtype
        assert mp_x == m_pad
    assert m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    assert run_start.shape == (m_pad,)
    assert run_off.shape == ((m_pad // TM) * (len(_RUN_SIZES) + 1),)
    if tb is None:
        tb = streamed_dw_tile(stream_w, block_w, sdtype.itemsize)
    if tb is None:
        raise ValueError(
            f"streamed dW tile working set exceeds VMEM budget for "
            f"W_stream={stream_w}; gate calls with ops.fused_supported")
    n_buffers = N_BUFFERS if n_buffers is None else n_buffers
    grid = (block_w // tb, m_pad // TM)
    scratch = [pltpu.VMEM((n_buffers, TM, stream_w), sdtype),
               pltpu.SemaphoreType.DMA((n_buffers,))]
    blk_spec = pl.BlockSpec((TM, tb), lambda b, m, *s: (m, b))
    if stream_x:
        in_specs = [pl.BlockSpec(memory_space=pltpu.ANY), blk_spec]
        operands = [row_src, run_start, run_off, tile_expert, x, g]
        out_spec = pl.BlockSpec(
            (1, k_pad, tb), lambda b, m, rs, rst, rl, te: (te[m], 0, b))
        kernel = _dw_stream_x_kernel
    else:
        in_specs = [pl.BlockSpec(memory_space=pltpu.ANY), blk_spec]
        operands = [row_src, run_start, run_off, tile_expert, g, x]
        out_spec = pl.BlockSpec(
            (1, tb, n_pad), lambda b, m, rs, rst, rl, te: (te[m], b, 0))
        if gate_tiles is not None:
            assert gate_tiles.shape == (m_pad // TM, TM)
            in_specs.append(pl.BlockSpec((1, TM), lambda b, m, *s: (m, 0)))
            operands.append(gate_tiles)
            kernel = _dw_stream_g_gate_kernel
        else:
            kernel = _dw_stream_g_kernel

    return pl.pallas_call(
        functools.partial(kernel, n_buffers=n_buffers),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, k_pad, n_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)


def _fused_w2_kernel(tile_expert_ref, u_ref, w2_ref, gate_ref, o_ref):
    acc = jnp.dot(u_ref[...], w2_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] = (acc * gate_ref[0][:, None]).astype(o_ref.dtype)


def cvmm_fused_w2_pallas(u_pad: jax.Array, tile_expert: jax.Array,
                         w2: jax.Array, gate_tiles: jax.Array,
                         *, interpret: bool = False,
                         tn: int | None = None) -> jax.Array:
    """Grouped GEMM with the per-row gate multiply fused into the epilogue.

    u_pad (M_pad, G_pad) tile-aligned; w2 (E, G_pad, N_pad);
    gate_tiles (M_pad//TM, TM) float32. Returns (M_pad, N_pad)."""
    m_pad, g_pad = u_pad.shape
    e, g_w, n_pad = w2.shape
    assert g_w == g_pad and m_pad % TM == 0
    assert g_pad % LANE == 0 and n_pad % LANE == 0
    assert gate_tiles.shape == (m_pad // TM, TM)
    if tn is None:
        tn = _pick_tn(g_pad, n_pad, u_pad.dtype.itemsize)
    tn = _require_tn(tn, "cvmm_fused_w2_pallas", g_pad)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fused_w2_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, g_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, g_pad, tn), lambda i, j, te: (te[i], 0, j)),
                pl.BlockSpec((1, TM), lambda i, j, te: (i, 0)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), u_pad.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, u_pad, w2, gate_tiles)
