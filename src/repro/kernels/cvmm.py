"""Pallas TPU kernels for CVMM — conditional (grouped) matmul, the paper's CUDA
kernel adapted to the TPU memory hierarchy (DESIGN.md Sec. 4).

Layout contract (established by ops.py, shared by every kernel here)
--------------------------------------------------------------------
Rows are sorted by expert and each expert's row-range is padded to a multiple of
the row tile TM, so **every (TM, K) row tile belongs to exactly one expert**.
ops.py computes this layout ONCE per MoE call into a ``CvmmPlan``:

  ``new_pos``     (M,)        tile-aligned slot of sorted row i
  ``row_src``     (M_pad,)    source row in the *unsorted* activations for each
                              padded slot; slack slots hold the sentinel N (one
                              past the last row) so XLA-side scatters drop them
  ``tile_expert`` (M_pad/TM,) row-tile index -> expert id (non-decreasing)
  ``gate_tiles``  (M_pad/TM, TM) float32 gate per padded slot, 0 on slack

``tile_expert`` is scalar-prefetched; BlockSpec index_maps use it to stream the
right expert's weight block HBM->VMEM. This replaces the CUDA kernel's
shared-memory reuse of the sorted expert matrix with Mosaic-scheduled DMA of one
(K, TN) weight tile per grid step. The plan is threaded through forward AND
backward via custom_vjp residuals, so backward never re-derives the layout.

Unfused kernels (building blocks, also the backward pass of the fused path)
  cvmm_pallas     out[t] = x[t] @ w[tile_expert[t]]        grid (m_tiles, n_tiles)
  cvmm_dw_pallas  dw[e]  = sum_{t: expert(t)=e} x[t]^T g[t] grid (k, n, m); m
                  innermost — tile_expert is non-decreasing, so output-block
                  revisits are consecutive and accumulation is legal on TPU.

Fused forward pipeline (one HBM round-trip per matmul, nothing else)
  cvmm_fused_w1_pallas   gather + GEMM + activation(/GLU) epilogue. The
      unsorted activations stay in HBM (``pltpu.ANY`` memory space) — the
      kernel never requires whole-array VMEM residency, so it scales to
      production token counts. ``row_src`` is scalar-prefetched and drives a
      double-buffered row-DMA pipeline: on the first N-tile of row tile ``i``
      the kernel waits for tile ``i``'s gather (issued one tile earlier into
      one of two (TM, K) VMEM scratch buffers via ``pltpu.make_async_copy``)
      and immediately starts tile ``i+1``'s gather into the other buffer, so
      the HBM row reads overlap the MXU work of the current tile. Slack slots
      (sentinel ``row_src``) are *skipped*, not clamped-gathered: their scratch
      rows are zeroed, so slack outputs are finite and killed downstream by the
      zero gate + scatter-drop. With GLU both W1 and W1g blocks are read in the
      same grid pass and u = act(x@w1) * (x@w1g) is written directly — the
      materialized (N*K, d) gather, the x_pad scatter, and the standalone
      activation pass all disappear.
  cvmm_fused_w2_pallas   GEMM + per-row gate multiply in the epilogue, so
      ``y_sorted * g_flat[perm]`` is never a separate XLA pass.
  cvmm_gather_rows_pallas  the same double-buffered row-DMA pipeline as a bare
      gather: unsorted HBM rows -> tile-aligned (M_pad, K) layout, zeros on
      slack. The backward pass uses it to materialize its (single) gathered
      operands with the streamed plan instead of an XLA-level take.

VMEM working set per grid step: two (TM, K) gather buffers + the (pipelined)
weight and output tiles — independent of the activation row count
(``fused_w1_tn`` does the accounting; ``ops.fused_supported`` now gates only
on this tile-level residency).

dX reuses the forward kernel with w transposed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import act_fn
from .compat import tpu_compiler_params

TM = 128            # row tile (MXU-aligned)
LANE = 128          # lane multiple for K / N
VMEM_BUDGET = 12 * 1024 * 1024
N_BUFFERS = 2       # gather scratch slots (double buffering)

# Activations that are elementwise (tile-local) and therefore legal to apply
# inside a kernel epilogue on an (TM, TN) tile.
FUSIBLE_ACTIVATIONS = ("relu", "gelu", "silu", "identity")


def _pick_tn(k_pad: int, n_pad: int, bytes_per_el: int) -> int:
    """Largest N tile (multiple of 128, <= n_pad) whose working set fits VMEM."""
    for tn in (512, 384, 256, 128):
        if tn > n_pad:
            continue
        if n_pad % tn:
            continue
        ws = TM * k_pad * bytes_per_el + k_pad * tn * bytes_per_el + TM * tn * 4
        if ws <= VMEM_BUDGET:
            return tn
    return 128


def fused_w1_tn(k_pad: int, g_pad: int, bytes_per_el: int,
                n_weights: int, n_out: int):
    """Largest fitting N tile for the streamed gather-fused w1 kernel, or None.

    Models the kernel's FULL per-step working set — two (TM, K) gather scratch
    buffers, plus the weight tiles and output tiles (3 with GLU + save_preact)
    at 2x for Mosaic's automatic pipeline double-buffering of blocked operands.
    The activations stream row-by-row from HBM, so — unlike the retired
    whole-x-resident kernel — the row count does not appear here at all.
    Returns None rather than silently under-tiling when nothing fits: callers
    must fall back to the unfused path instead of compiling a kernel that
    exhausts VMEM."""
    scratch = N_BUFFERS * TM * k_pad * bytes_per_el
    for tn in (512, 384, 256, 128):
        if tn > g_pad or g_pad % tn:
            continue
        ws = scratch + 2 * (n_weights * k_pad * tn * bytes_per_el
                            + n_out * TM * tn * max(bytes_per_el, 4))
        if ws <= VMEM_BUDGET:
            return tn
    return None


def legacy_whole_x_rows(k_pad: int, bytes_per_el: int, n_weights: int,
                        n_out: int) -> int:
    """Max activation rows the RETIRED whole-x-resident w1 kernel accepted.

    The pre-streaming kernel kept the entire (N, K) unsorted activation block
    in VMEM next to one (TM, K) gather scratch, the weight tiles and the output
    tiles (at the minimum tn=128), so its residency gate capped the row count
    at roughly (VMEM_BUDGET - tiles) / row_bytes. Kept as the reference point
    for tests and benchmarks that must demonstrate the streamed kernel working
    far beyond this boundary; reads ``VMEM_BUDGET`` at call time so tests can
    shrink the budget to sweep the boundary cheaply."""
    tiles = (TM * k_pad * bytes_per_el
             + n_weights * k_pad * 128 * bytes_per_el
             + n_out * TM * 128 * max(bytes_per_el, 4))
    return max((VMEM_BUDGET - tiles) // (k_pad * bytes_per_el), 0)


# ---------------------------------------------------------------------------
# Forward kernel (unfused building block)
# ---------------------------------------------------------------------------

def _fwd_kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    # x_ref: (TM, K), w_ref: (1, K, TN), o_ref: (TM, TN)
    acc = jnp.dot(x_ref[...], w_ref[0],
                  preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def cvmm_pallas(x_pad: jax.Array, tile_expert: jax.Array, w: jax.Array,
                *, interpret: bool = False) -> jax.Array:
    """x_pad (M_pad, K_pad) sorted+tile-aligned rows; tile_expert (M_pad//TM,) int32;
    w (E, K_pad, N_pad). Returns (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    e, k_w, n_pad = w.shape
    assert k_w == k_pad and m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    tn = _pick_tn(k_pad, n_pad, x_pad.dtype.itemsize)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, k_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, k_pad, tn), lambda i, j, te: (te[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), x_pad.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, w)


# ---------------------------------------------------------------------------
# dW kernel (grouped outer-product accumulation)
# ---------------------------------------------------------------------------

def _dw_kernel(tile_expert_ref, x_ref, g_ref, o_ref):
    # grid (k_tiles, n_tiles, m_tiles); m innermost.
    m = pl.program_id(2)
    e_now = tile_expert_ref[m]
    e_prev = tile_expert_ref[jnp.maximum(m - 1, 0)]
    first = jnp.logical_or(m == 0, e_now != e_prev)
    acc = jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (TK, TN)

    @pl.when(first)
    def _init():
        o_ref[0] = acc

    @pl.when(jnp.logical_not(first))
    def _acc():
        o_ref[0] += acc


def cvmm_dw_pallas(x_pad: jax.Array, tile_expert: jax.Array, g_pad: jax.Array,
                   n_experts: int, *, interpret: bool = False) -> jax.Array:
    """dW (E, K_pad, N_pad) float32 from tile-aligned x (M_pad, K_pad), g (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    _, n_pad = g_pad.shape
    assert m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    tk = _pick_tn(TM, k_pad, x_pad.dtype.itemsize)
    tn = _pick_tn(TM, n_pad, g_pad.dtype.itemsize)
    grid = (k_pad // tk, n_pad // tn, m_pad // TM)

    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, tk), lambda k, n, m, te: (m, k)),
                pl.BlockSpec((TM, tn), lambda k, n, m, te: (m, n)),
            ],
            out_specs=pl.BlockSpec((1, tk, tn), lambda k, n, m, te: (te[m], k, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, k_pad, n_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, g_pad)


# ---------------------------------------------------------------------------
# Fused forward kernels
# ---------------------------------------------------------------------------

def _gather_issue(t, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows: int):
    """Zero slot ``t % N_BUFFERS`` and start the row DMAs for row tile ``t``.

    One ``make_async_copy`` per real row, HBM -> VMEM scratch; slack slots
    (sentinel ``row_src`` >= n_rows) are *skipped*, so their scratch rows keep
    the zeros written here — the downstream GEMM sees finite values and the
    zero gate / scatter-drop kills the result. All copies of a tile signal the
    slot's semaphore; ``_gather_wait`` reconstructs the same descriptors."""
    slot = jax.lax.rem(t, N_BUFFERS)
    xs_ref[slot] = jnp.zeros(xs_ref.shape[1:], xs_ref.dtype)

    def body(r, _):
        src = row_src_ref[t * TM + r]

        @pl.when(src < n_rows)
        def _():
            pltpu.make_async_copy(x_hbm.at[pl.ds(src, 1), :],
                                  xs_ref.at[slot, pl.ds(r, 1), :],
                                  sem_ref.at[slot]).start()
        return 0

    jax.lax.fori_loop(0, TM, body, 0)


def _gather_wait(t, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows: int):
    """Wait for every row DMA issued by ``_gather_issue`` for row tile ``t``."""
    slot = jax.lax.rem(t, N_BUFFERS)

    def body(r, _):
        src = row_src_ref[t * TM + r]

        @pl.when(src < n_rows)
        def _():
            pltpu.make_async_copy(x_hbm.at[pl.ds(src, 1), :],
                                  xs_ref.at[slot, pl.ds(r, 1), :],
                                  sem_ref.at[slot]).wait()
        return 0

    jax.lax.fori_loop(0, TM, body, 0)


def _stream_tile(i, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows: int):
    """Double-buffered gather step for row tile ``i`` (grid dim 0, sequential).

    Waits for tile ``i``'s rows (issued one tile earlier; warm-up issues tile 0
    inline) and immediately starts tile ``i+1``'s DMAs into the other scratch
    slot, so the HBM reads of the next tile overlap this tile's MXU work.
    Returns the slot holding tile ``i``."""
    m_tiles = pl.num_programs(0)

    @pl.when(i == 0)
    def _warmup():
        _gather_issue(0, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows)

    _gather_wait(i, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows)

    @pl.when(i + 1 < m_tiles)
    def _prefetch_next():
        _gather_issue(i + 1, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows)

    return jax.lax.rem(i, N_BUFFERS)


def _fused_w1_body(row_src_ref, x_hbm, w1_ref, w1g_ref, o_u_ref, o_h_ref,
                   o_hg_ref, xs_ref, sem_ref, *, act_name: str, n_rows: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _():
        _stream_tile(i, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows)
    xt = xs_ref[jax.lax.rem(i, N_BUFFERS)]
    h = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    u = act_fn(act_name)(h)
    if w1g_ref is not None:
        hg = jnp.dot(xt, w1g_ref[0],
                     preferred_element_type=jnp.float32)
        u = u * hg
        if o_hg_ref is not None:
            o_hg_ref[...] = hg.astype(o_hg_ref.dtype)
    if o_h_ref is not None:
        o_h_ref[...] = h.astype(o_h_ref.dtype)
    o_u_ref[...] = u.astype(o_u_ref.dtype)


def _k_w1(rs, te, x, w1, o_u, xs, sem, **kw):
    _fused_w1_body(rs, x, w1, None, o_u, None, None, xs, sem, **kw)


def _k_w1_save(rs, te, x, w1, o_u, o_h, xs, sem, **kw):
    _fused_w1_body(rs, x, w1, None, o_u, o_h, None, xs, sem, **kw)


def _k_w1_glu(rs, te, x, w1, w1g, o_u, xs, sem, **kw):
    _fused_w1_body(rs, x, w1, w1g, o_u, None, None, xs, sem, **kw)


def _k_w1_glu_save(rs, te, x, w1, w1g, o_u, o_h, o_hg, xs, sem, **kw):
    _fused_w1_body(rs, x, w1, w1g, o_u, o_h, o_hg, xs, sem, **kw)


def cvmm_fused_w1_pallas(x: jax.Array, row_src: jax.Array,
                         tile_expert: jax.Array, w1: jax.Array,
                         w1g: jax.Array | None, *, act_name: str,
                         save_preact: bool = False,
                         interpret: bool = False):
    """Streamed gather-fused grouped GEMM with activation(/GLU) epilogue.

    x (N_rows, K_pad) — the UNSORTED activations, left in HBM (``pltpu.ANY``)
    and streamed row-by-row through a double-buffered async-copy pipeline (see
    ``_stream_tile``); the row count is unconstrained — no multiple-of-8
    padding, no whole-array VMEM residency. row_src (M_pad,) int32 maps padded
    slots to rows of x (sentinel >= N_rows on slack; those rows are skipped and
    zero-filled); w1/w1g (E, K_pad, G_pad). Returns u (M_pad, G_pad) in the
    tile-aligned sorted layout, already activated (and gated when w1g given).

    ``save_preact=True`` (training: the custom_vjp forward rule) additionally
    writes the pre-activations h (and hg with GLU) in the same grid pass, so
    the backward pass needs no recompute GEMMs; returns (u, h[, hg])."""
    n_rows, k_pad = x.shape
    e, k_w, g_pad = w1.shape
    m_pad = row_src.shape[0]
    assert k_w == k_pad and m_pad % TM == 0
    assert k_pad % LANE == 0 and g_pad % LANE == 0
    n_weights = 2 if w1g is not None else 1
    n_out = (1 + n_weights) if save_preact else 1
    tn = fused_w1_tn(k_pad, g_pad, x.dtype.itemsize, n_weights, n_out)
    if tn is None:
        raise ValueError(
            f"fused w1 tile working set exceeds VMEM budget for K_pad="
            f"{k_pad}; gate calls with ops.fused_supported")
    grid = (m_pad // TM, g_pad // tn)

    w_spec = pl.BlockSpec((1, k_pad, tn), lambda i, j, rs, te: (te[i], 0, j))
    o_spec = pl.BlockSpec((TM, tn), lambda i, j, rs, te: (i, j))
    o_shape = jax.ShapeDtypeStruct((m_pad, g_pad), x.dtype)
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY), w_spec]
    operands = [row_src, tile_expert, x, w1]
    if w1g is not None:
        in_specs.append(w_spec)
        operands.append(w1g)
        kernel = _k_w1_glu_save if save_preact else _k_w1_glu
    else:
        kernel = _k_w1_save if save_preact else _k_w1
    kernel = functools.partial(kernel, act_name=act_name, n_rows=n_rows)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=[o_spec] * n_out,
            scratch_shapes=[pltpu.VMEM((N_BUFFERS, TM, k_pad), x.dtype),
                            pltpu.SemaphoreType.DMA((N_BUFFERS,))],
        ),
        out_shape=[o_shape] * n_out,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0] if n_out == 1 else tuple(out)


def _gather_rows_kernel(row_src_ref, x_hbm, o_ref, xs_ref, sem_ref,
                        *, n_rows: int):
    i = pl.program_id(0)
    slot = _stream_tile(i, row_src_ref, x_hbm, xs_ref, sem_ref, n_rows)
    o_ref[...] = xs_ref[slot]


def cvmm_gather_rows_pallas(x: jax.Array, row_src: jax.Array,
                            *, interpret: bool = False) -> jax.Array:
    """Streamed gather: unsorted HBM rows -> tile-aligned (M_pad, K_pad) copy.

    The same double-buffered row-DMA pipeline as the fused w1 kernel, with the
    scratch tile written straight to the blocked output (slack slots zero).
    The backward pass uses this to materialize its gathered operands for the
    dW / gather-transpose kernels with the SAME streamed plan as forward — the
    unsorted array never needs whole-array VMEM residency there either."""
    n_rows, k_pad = x.shape
    m_pad = row_src.shape[0]
    assert m_pad % TM == 0 and k_pad % LANE == 0
    return pl.pallas_call(
        functools.partial(_gather_rows_kernel, n_rows=n_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m_pad // TM,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec((TM, k_pad), lambda i, rs: (i, 0)),
            scratch_shapes=[pltpu.VMEM((N_BUFFERS, TM, k_pad), x.dtype),
                            pltpu.SemaphoreType.DMA((N_BUFFERS,))],
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(row_src, x)


def _fused_w2_kernel(tile_expert_ref, u_ref, w2_ref, gate_ref, o_ref):
    acc = jnp.dot(u_ref[...], w2_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] = (acc * gate_ref[0][:, None]).astype(o_ref.dtype)


def cvmm_fused_w2_pallas(u_pad: jax.Array, tile_expert: jax.Array,
                         w2: jax.Array, gate_tiles: jax.Array,
                         *, interpret: bool = False) -> jax.Array:
    """Grouped GEMM with the per-row gate multiply fused into the epilogue.

    u_pad (M_pad, G_pad) tile-aligned; w2 (E, G_pad, N_pad);
    gate_tiles (M_pad//TM, TM) float32. Returns (M_pad, N_pad)."""
    m_pad, g_pad = u_pad.shape
    e, g_w, n_pad = w2.shape
    assert g_w == g_pad and m_pad % TM == 0
    assert g_pad % LANE == 0 and n_pad % LANE == 0
    assert gate_tiles.shape == (m_pad // TM, TM)
    tn = _pick_tn(g_pad, n_pad, u_pad.dtype.itemsize)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fused_w2_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, g_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, g_pad, tn), lambda i, j, te: (te[i], 0, j)),
                pl.BlockSpec((1, TM), lambda i, j, te: (i, 0)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), u_pad.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, u_pad, w2, gate_tiles)
