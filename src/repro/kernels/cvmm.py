"""Pallas TPU kernels for CVMM — conditional (grouped) matmul, the paper's CUDA
kernel adapted to the TPU memory hierarchy (DESIGN.md Sec. 4).

Layout contract (established by ops.py): rows are sorted by expert and each expert's
row-range is padded to a multiple of the row tile TM, so **every (TM, K) row tile
belongs to exactly one expert**. A scalar-prefetch array ``tile_expert`` maps row-tile
index -> expert id; BlockSpec index_maps use it to stream the right expert's weight
block HBM->VMEM. This replaces the CUDA kernel's shared-memory reuse of the sorted
expert matrix with Mosaic-scheduled DMA of one (K, TN) weight tile per grid step.

Forward:  out[t] = x[t] @ w[tile_expert[t]]          grid (m_tiles, n_tiles)
dW:       dw[e]  = sum_{t: expert(t)=e} x[t]^T g[t]  grid (k_tiles, n_tiles, m_tiles)
          (m innermost; tile_expert is non-decreasing, so output-block revisits are
          consecutive and accumulation is legal on TPU.)
dX reuses the forward kernel with w transposed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TM = 128            # row tile (MXU-aligned)
LANE = 128          # lane multiple for K / N
VMEM_BUDGET = 12 * 1024 * 1024


def _pick_tn(k_pad: int, n_pad: int, bytes_per_el: int) -> int:
    """Largest N tile (multiple of 128, <= n_pad) whose working set fits VMEM."""
    for tn in (512, 384, 256, 128):
        if tn > n_pad:
            continue
        if n_pad % tn:
            continue
        ws = TM * k_pad * bytes_per_el + k_pad * tn * bytes_per_el + TM * tn * 4
        if ws <= VMEM_BUDGET:
            return tn
    return 128


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    # x_ref: (TM, K), w_ref: (1, K, TN), o_ref: (TM, TN)
    acc = jnp.dot(x_ref[...], w_ref[0],
                  preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def cvmm_pallas(x_pad: jax.Array, tile_expert: jax.Array, w: jax.Array,
                *, interpret: bool = False) -> jax.Array:
    """x_pad (M_pad, K_pad) sorted+tile-aligned rows; tile_expert (M_pad//TM,) int32;
    w (E, K_pad, N_pad). Returns (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    e, k_w, n_pad = w.shape
    assert k_w == k_pad and m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    tn = _pick_tn(k_pad, n_pad, x_pad.dtype.itemsize)
    grid = (m_pad // TM, n_pad // tn)

    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, k_pad), lambda i, j, te: (i, 0)),
                pl.BlockSpec((1, k_pad, tn), lambda i, j, te: (te[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((TM, tn), lambda i, j, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), x_pad.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, w)


# ---------------------------------------------------------------------------
# dW kernel (grouped outer-product accumulation)
# ---------------------------------------------------------------------------

def _dw_kernel(tile_expert_ref, x_ref, g_ref, o_ref):
    # grid (k_tiles, n_tiles, m_tiles); m innermost.
    m = pl.program_id(2)
    e_now = tile_expert_ref[m]
    e_prev = tile_expert_ref[jnp.maximum(m - 1, 0)]
    first = jnp.logical_or(m == 0, e_now != e_prev)
    acc = jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (TK, TN)

    @pl.when(first)
    def _init():
        o_ref[0] = acc

    @pl.when(jnp.logical_not(first))
    def _acc():
        o_ref[0] += acc


def cvmm_dw_pallas(x_pad: jax.Array, tile_expert: jax.Array, g_pad: jax.Array,
                   n_experts: int, *, interpret: bool = False) -> jax.Array:
    """dW (E, K_pad, N_pad) float32 from tile-aligned x (M_pad, K_pad), g (M_pad, N_pad)."""
    m_pad, k_pad = x_pad.shape
    _, n_pad = g_pad.shape
    assert m_pad % TM == 0 and k_pad % LANE == 0 and n_pad % LANE == 0
    tk = _pick_tn(TM, k_pad, x_pad.dtype.itemsize)
    tn = _pick_tn(TM, n_pad, g_pad.dtype.itemsize)
    grid = (k_pad // tk, n_pad // tn, m_pad // TM)

    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, tk), lambda k, n, m, te: (m, k)),
                pl.BlockSpec((TM, tn), lambda k, n, m, te: (m, n)),
            ],
            out_specs=pl.BlockSpec((1, tk, tn), lambda k, n, m, te: (te[m], k, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, k_pad, n_pad), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_expert, x_pad, g_pad)
