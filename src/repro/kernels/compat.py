"""Pallas TPU API compatibility shims shared by the kernel modules.

The TPU compiler-params class was renamed across JAX releases:
``pltpu.TPUCompilerParams`` (jax 0.4.x) became ``pltpu.CompilerParams`` in
later releases. Every ``pallas_call`` in this package goes through
``tpu_compiler_params`` so the rename is absorbed in exactly one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(*, dimension_semantics):
    """Build compiler params with per-grid-dim semantics on any JAX version."""
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))
