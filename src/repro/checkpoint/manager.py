"""Fault-tolerant checkpointing.

Guarantees:
  * atomicity  -- writes go to ``<dir>/tmp.<step>`` and are renamed to ``step_<n>``
                  only after fsync; a crash mid-save never corrupts the latest
                  checkpoint ("latest" is resolved by scanning committed dirs).
  * async      -- `save(..., blocking=False)` snapshots device arrays to host
                  (device_get) then writes on a background thread; training continues.
  * keep-N     -- old checkpoints garbage-collected after a successful commit.
  * elasticity -- arrays are saved UNSHARDED (gathered) with their pytree paths;
                  `restore(..., shardings=...)` re-shards onto any mesh, so a job can
                  restart on a different topology (elastic scaling). On multi-host
                  deployments process 0 writes (single-controller model); a
                  per-host-shard format is a straightforward extension noted in
                  DESIGN.md.
  * iterator state + step + RNG key are first-class checkpoint content.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def _to_savable(a: np.ndarray):
    """npz cannot store ml_dtypes (bf16 etc.); store a bit-view + dtype string."""
    if a.dtype.kind == "V" or str(a.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8), str(a.dtype)
    return a, str(a.dtype)


def _from_savable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(a.dtype) != dtype_str:
        import ml_dtypes
        return a.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return a


def save_pytree(path: str, tree, extra: Optional[Dict] = None) -> None:
    keys, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    dtypes = []
    for i, l in enumerate(leaves):
        a, ds = _to_savable(np.asarray(jax.device_get(l)))
        arrays[f"arr_{i}"] = a
        dtypes.append(ds)
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {"keys": keys, "dtypes": dtypes, "extra": extra or {}}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_pytree(path: str, like, shardings=None):
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs).

    shardings: optional matching pytree of NamedShardings -> device_put re-shards
    (elastic restore onto a new mesh).
    """
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"arr_{i}"] for i in range(len(z.files))]
    keys_here, like_leaves, treedef = _flatten_with_paths(like)
    meta = json.load(open(os.path.join(path, "meta.json")))
    arrays = [_from_savable(a, ds) for a, ds in
              zip(arrays, meta.get("dtypes", [str(a.dtype) for a in arrays]))]
    by_key = dict(zip(meta["keys"], arrays))
    out = []
    for k, l in zip(keys_here, like_leaves):
        if k not in by_key:
            raise KeyError(f"checkpoint missing leaf {k}")
        a = by_key[k]
        want_dtype = getattr(l, "dtype", a.dtype)
        out.append(np.asarray(a).astype(want_dtype))
    tree = treedef.unflatten(out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, meta["extra"]


_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ query
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------- save
    def _write(self, step: int, host_tree, extra: Dict) -> None:
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(tmp, host_tree, extra)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def save(self, step: int, tree, extra: Optional[Dict] = None,
             blocking: Optional[bool] = None) -> None:
        self.wait()                                   # one in-flight save at a time
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                           tree)
        extra = dict(extra or {}, step=step)
        block = (not self.async_save) if blocking is None else blocking
        if block:
            self._write(step, host_tree, extra)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------------- restore
    def restore(self, like, step: Optional[int] = None, shardings=None):
        """Returns (tree, extra) or (None, None) when no checkpoint exists."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        return load_pytree(path, like, shardings)
