from .lm import LM
from .registry import build_model

__all__ = ["LM", "build_model"]
