"""Attention: GQA with chunked-flash (pure JAX online softmax), sliding-window,
Transformer-XL relative-position attention, and KV-cache decode.

The chunked path is the memory-bounded workhorse for the 32k prefill / 4k train
shapes: a lax.scan over KV chunks carrying (m, l, acc) online-softmax state, so the
(Sq, Sk) score matrix is never materialized. A Pallas flash kernel covers the TPU
hot path (kernels/flash_attention.py); this module is the composable reference that
XLA also compiles well (it is the same loop structure the kernel uses).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import AttentionConfig, ModelConfig
from .layers import apply_rope, rms_norm_simple, sinusoid_positions


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    a = cfg.attention
    d = cfg.d_model
    kq, kk, kv, ko, kr = jax.random.split(key, 5)
    std = (d ** -0.5)
    p = {
        "wq": std * jax.random.normal(kq, (d, a.q_dim), dtype),
        "wk": std * jax.random.normal(kk, (d, a.kv_dim), dtype),
        "wv": std * jax.random.normal(kv, (d, a.kv_dim), dtype),
        "wo": (a.q_dim ** -0.5) * jax.random.normal(ko, (a.q_dim, d), dtype),
    }
    if a.qk_norm:
        p["q_scale"] = jnp.ones((a.head_dim,), dtype)
        p["k_scale"] = jnp.ones((a.head_dim,), dtype)
    if a.kind == "xl_rel":
        p["w_r"] = std * jax.random.normal(kr, (d, a.q_dim), dtype)
        p["u_bias"] = jnp.zeros((a.n_heads, a.head_dim), dtype)
        p["v_bias"] = jnp.zeros((a.n_heads, a.head_dim), dtype)
    return p


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _gqa_expand(q, k, v):
    """Reshape for grouped-query attention: q (B,S,H,D) -> (B,S,KV,Grp,D)."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    return q.reshape(b, s, kvh, h // kvh, dh)


# ---------------------------------------------------------------------------
# Chunked-flash core (online softmax over KV chunks)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: int = 0, scale: float,
                    q_offset: int = 0, kv_chunk: int = 2048,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q (B,Sq,H,D), k/v (B,Sk,KV,D) -> (B,Sq,H,D); never materializes (Sq,Sk).

    q_offset: absolute position of q[0] relative to k[0] (for caches/memory).
    kv_len: optional (B,) valid KV lengths (decode against a partially-filled cache).
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    grp = h // kvh
    qg = q.reshape(b, sq, kvh, grp, dh)
    nchunks = -(-sk // kv_chunk)
    sk_pad = nchunks * kv_chunk
    if sk_pad != sk:
        pad = [(0, 0), (0, sk_pad - sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(b, nchunks, kv_chunk, kvh, dh)
    vc = v.reshape(b, nchunks, kv_chunk, kvh, dh)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, cidx = xs
        k_pos = cidx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < sk)[None, :]
        if kv_len is not None:
            s = jnp.where((k_pos[None, :] < kv_len[:, None])[:, None, None, None, :],
                          s, -jnp.inf)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, grp, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, grp, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, grp, sq, dh), jnp.float32)
    # checkpoint per chunk: backward recomputes the (sq, chunk) probability block
    # instead of storing one per scan step (which would be O(Sq*Sk) memory -- the
    # exact failure mode flash attention exists to avoid).
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nchunks)))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dh)      # (B,Sq,KV,Grp,D)->(B,Sq,H,D)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     scale: float, q_pos, window: int = 0,
                     kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Single-token attention against a (possibly sequence-sharded) KV cache.

    q (B,1,H,D), k/v (B,Smax,KV,D). The (B,H,Smax) score tensor is small at decode,
    so no online softmax is needed; XLA SPMD reduces over a sharded Smax with a psum,
    which is what makes a sequence-sharded KV cache work for the long_500k shape.
    q_pos may be a scalar (lockstep decode) or (B,) per-request positions
    (continuous batching: each lane sits at its own depth).
    """
    b, _, h, dh = q.shape
    smax, kvh = k.shape[1], k.shape[2]
    grp = h // kvh
    qg = q.reshape(b, kvh, grp, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(smax)
    mask = jnp.ones((smax,), bool)
    if kv_len is not None:
        mask = pos[None, :] < kv_len[:, None]               # (B, Smax)
    if window:
        qp = jnp.asarray(q_pos, jnp.int32).reshape(-1)      # scalar or (B,)
        wmask = pos[None, :] > qp[:, None] - window         # (1 or B, Smax)
        mask = (mask if mask.ndim == 2 else mask[None, :]) & wmask
    if mask.ndim == 1:
        mask = mask[None, :]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged (block) KV cache — serving's continuous-batching layout
# ---------------------------------------------------------------------------

def paged_attend(q: jax.Array, k: jax.Array, v: jax.Array, cache: Dict,
                 block_table: jax.Array, cache_index, seq_lens, *,
                 scale: float, window: int = 0,
                 kv_chunk: int = 2048) -> Tuple[jax.Array, Dict]:
    """Attention against a paged KV pool (see serving/__init__ for the full
    block-table/KV-page contract).

    cache: {"k": (P, ps, KV, D), "v": ...} — a pool of P fixed-size pages
    shared by all requests; page 0 is the reserved null/scratch page.
    block_table (B, n_blocks) maps each request's logical page j to its
    physical page id (0 = unallocated). Two modes:

    decode (Sq == 1): ``cache_index`` is the (B,) absolute write position of
    each lane's token; the new K/V scatters into (page, offset) slots and
    attention runs over the request's gathered pages with per-lane
    ``kv_len = pos + 1`` masking (scratch-page garbage beyond a lane's
    length is masked out, not read around).

    prefill chunk (Sq > 1, B == 1): ``cache_index`` is the scalar absolute
    start of this chunk and ``seq_lens`` the (1,) valid token count within
    it — padded chunk tail tokens target page id P, which is out of bounds,
    so their writes DROP; their attention rows compute garbage the caller
    discards (the engine reads logits at length-1 only).
    """
    b, sq = q.shape[0], q.shape[1]
    n_pages, ps = cache["k"].shape[0], cache["k"].shape[1]
    cdt = cache["k"].dtype
    if sq == 1:
        pos = jnp.asarray(cache_index, jnp.int32)               # (B,)
        page = jnp.take_along_axis(block_table, (pos // ps)[:, None],
                                   axis=1)[:, 0]
        off = pos % ps
        ck = cache["k"].at[page, off].set(k[:, 0].astype(cdt))
        cv = cache["v"].at[page, off].set(v[:, 0].astype(cdt))
        gk = ck[block_table].reshape(b, -1, *ck.shape[2:])
        gv = cv[block_table].reshape(b, -1, *cv.shape[2:])
        out = decode_attention(q, gk.astype(q.dtype), gv.astype(q.dtype),
                               scale=scale, q_pos=pos, window=window,
                               kv_len=pos + 1)
    else:
        if b != 1:
            raise NotImplementedError("paged prefill runs one request per "
                                      "chunk (B == 1)")
        start = jnp.asarray(cache_index, jnp.int32)             # scalar
        length = jnp.asarray(seq_lens, jnp.int32).reshape(-1)[0]
        pos = start + jnp.arange(sq)
        valid = jnp.arange(sq) < length
        lpage = jnp.minimum(pos // ps, block_table.shape[1] - 1)
        page = jnp.where(valid, block_table[0][lpage], n_pages)  # OOB: drop
        off = pos % ps
        ck = cache["k"].at[page, off].set(k[0].astype(cdt), mode="drop")
        cv = cache["v"].at[page, off].set(v[0].astype(cdt), mode="drop")
        gk = ck[block_table[0]].reshape(1, -1, *ck.shape[2:])
        gv = cv[block_table[0]].reshape(1, -1, *cv.shape[2:])
        out = flash_attention(q, gk.astype(q.dtype), gv.astype(q.dtype),
                              causal=True, window=window, scale=scale,
                              q_offset=start, kv_chunk=kv_chunk,
                              kv_len=(start + length)[None])
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Transformer-XL relative-position attention (paper's baseline architecture)
# ---------------------------------------------------------------------------

def _rel_shift(x: jax.Array) -> jax.Array:
    """(B,H,Sq,Sk) BD-term shift (Dai et al. 2019)."""
    b, h, sq, sk = x.shape
    x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (1, 0)))
    x = x.reshape(b, h, sk + 1, sq)[:, :, 1:, :]
    return x.reshape(b, h, sq, sk)


def xl_attention(params: Dict, q: jax.Array, k: jax.Array, v: jax.Array,
                 cfg: AttentionConfig, d_model: int) -> jax.Array:
    """q (B,Sq,H,D); k/v (B,Sk,H,D) where Sk = mem + Sq. Full (small-ctx) scores."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = cfg.softmax_scale or (dh ** -0.5)
    r = sinusoid_positions(sk, d_model, q.dtype)[::-1]        # distances sk-1..0
    r = (r @ params["w_r"].astype(q.dtype)).reshape(sk, h, dh)
    ac = jnp.einsum("bqhd,bkhd->bhqk", q + params["u_bias"].astype(q.dtype), k)
    bd = jnp.einsum("bqhd,khd->bhqk", q + params["v_bias"].astype(q.dtype), r)
    bd = _rel_shift(bd)
    s = (ac + bd).astype(jnp.float32) * scale
    q_pos = (sk - sq) + jnp.arange(sq)
    mask = q_pos[:, None] >= jnp.arange(sk)[None, :]
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# Full block-level apply
# ---------------------------------------------------------------------------

def apply_attention(params: Dict, x: jax.Array, cfg: ModelConfig, *,
                    kind: str = "", positions: Optional[jax.Array] = None,
                    cache: Optional[Dict] = None,
                    cache_index: Optional[jax.Array] = None,
                    memory: Optional[jax.Array] = None,
                    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    block_table: Optional[jax.Array] = None,
                    seq_lens: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Optional[Dict]]:
    """One attention sublayer (projections + core + output).

    cache: {"k": (B,Smax,KV,D), "v": ...} for decode; cache_index (B,) write pos.
    memory: XL segment memory (B, M, d_model), no grad.
    cross_kv: precomputed encoder K/V for cross-attention.
    block_table: (B, n_blocks) page table — switches the cache to the paged
    pool layout {"k": (P, ps, KV, D), ...} (see ``paged_attend``);
    ``seq_lens`` is its prefill-chunk valid-length vector.
    Returns (output, updated_cache).
    """
    a = cfg.attention
    kind = kind or a.kind
    b, s, d = x.shape
    scale = a.softmax_scale if a.softmax_scale else a.head_dim ** -0.5

    q = _split_heads(jnp.einsum("bsd,dq->bsq", x, params["wq"].astype(x.dtype)),
                     a.n_heads, a.head_dim)
    if cross_kv is not None:
        k, v = cross_kv
    else:
        src = x if memory is None else jnp.concatenate(
            [jax.lax.stop_gradient(memory.astype(x.dtype)), x], axis=1)
        k = _split_heads(jnp.einsum("bsd,dq->bsq", src, params["wk"].astype(x.dtype)),
                         a.n_kv_heads, a.head_dim)
        v = _split_heads(jnp.einsum("bsd,dq->bsq", src, params["wv"].astype(x.dtype)),
                         a.n_kv_heads, a.head_dim)

    if a.qk_norm:
        q = rms_norm_simple(q, params["q_scale"])
        k = rms_norm_simple(k, params["k_scale"])

    new_cache = None
    if kind == "xl_rel":
        out = xl_attention(params, q, k, v, a, d)
    else:
        if positions is None:
            positions = jnp.arange(s)
        if cfg.pos_encoding == "rope" and cross_kv is None:
            q = apply_rope(q, positions, a.rope_theta)
            k = apply_rope(k, positions, a.rope_theta)
        elif cfg.pos_encoding == "rope" and cross_kv is not None:
            q = apply_rope(q, positions, a.rope_theta)

        if cache is not None and cross_kv is None and block_table is not None:
            win = a.window if kind == "local" else 0
            out, new_cache = paged_attend(
                q, k, v, cache, block_table, cache_index, seq_lens,
                scale=scale, window=win, kv_chunk=a.kv_chunk)
        elif cache is not None and cross_kv is None:
            # decode: write new k/v at cache_index, attend over the filled prefix.
            idx = cache_index
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            new_cache = {"k": ck, "v": cv}
            kv_len = jnp.full((b,), idx + s, jnp.int32)
            win = a.window if kind == "local" else 0
            if s == 1:
                # decode: direct attention; causality via kv_len. Works with
                # sequence-sharded caches (SPMD psum over the seq reduction).
                out = decode_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                       scale=scale, q_pos=idx, window=win,
                                       kv_len=kv_len)
            else:
                # prefill: causal chunked-flash over the freshly written cache.
                out = flash_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                      causal=True, window=win, scale=scale,
                                      q_offset=idx, kv_chunk=a.kv_chunk,
                                      kv_len=kv_len)
        else:
            win = a.window if kind == "local" else 0
            causal = a.causal and cross_kv is None and kind != "noncausal"
            out = flash_attention(q, k, v, causal=causal,
                                  window=win, scale=scale, kv_chunk=a.kv_chunk)

    out = out.reshape(b, s, a.q_dim)
    y = jnp.einsum("bsq,qd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
    a = cfg.attention
    shape = (batch, max_len, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16) -> Dict:
    """One layer's paged KV pool: P pages of ps slots each, shared by all
    requests via block tables. Page 0 is the reserved null/scratch page —
    the allocator never hands it out, so unallocated block-table entries
    (value 0) absorb writes from inactive lanes harmlessly."""
    a = cfg.attention
    shape = (n_pages, page_size, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
