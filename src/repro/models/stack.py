"""Layer stack: scan-over-layers with heterogeneous layer patterns.

Depth is executed as lax.scan over "segments". A segment is (pattern entries,
repeats): uniform models have one segment ([attn+ffn], n_layers); gemma3's 5:1
local:global is ([local x5, global], 10) + remainder; zamba2 is ([ssm x5,
shared-block], 13) + remainder. Scanning keeps the HLO O(1) in depth -- essential for
compiling 62-layer models with 512 SPMD partitions in the dry-run.

'shared_*' entries reference ONE set of weights (zamba2's shared attention+MLP
block); they are closed over, not stacked, and every application reuses them (this is
exactly the shared-layer setting the paper's Limitations section motivates MoE for).

Caches mirror the segment structure: {'segments': [ {entry_i: stacked (repeats, ...)
arrays} ]}. The same scan drives train, prefill and decode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import BlockSpecEntry, ModelConfig
from ..sharding.logical import SP_RULES, with_logical_constraint
from .attention import (apply_attention, init_attention,
                        init_cache as init_attn_cache,
                        init_paged_cache as init_attn_paged_cache)
from .ffn import apply_ffn, init_ffn
from .layers import apply_norm, dropout, init_norm
from .mamba2 import apply_ssm, init_ssm, init_ssm_cache


@dataclass(frozen=True)
class Segment:
    entries: Tuple[BlockSpecEntry, ...]
    repeats: int


def plan_segments(cfg: ModelConfig, n_layers: Optional[int] = None) -> List[Segment]:
    n = n_layers if n_layers is not None else cfg.n_layers
    pattern = cfg.pattern or (BlockSpecEntry(mixer="attn", ffn="ffn"),)
    p = len(pattern)
    segs = []
    if n // p:
        segs.append(Segment(tuple(pattern), n // p))
    if n % p:
        segs.append(Segment(tuple(pattern[: n % p]), 1))
    return segs


def _needs_shared(cfg: ModelConfig) -> bool:
    return any(e.mixer == "shared_attn" or e.ffn == "shared_ffn"
               for e in (cfg.pattern or ()))


# ---------------------------------------------------------------------------
# One block (pattern entry)
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, entry: BlockSpecEntry, dtype,
               ep_degree: int = 0, cross: bool = False) -> Dict:
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if entry.mixer == "attn":
        p["norm1"] = init_norm(cfg, cfg.d_model, dtype)
        p["attn"] = init_attention(keys[0], cfg, dtype)
    elif entry.mixer == "ssm":
        p["norm1"] = init_norm(cfg, cfg.d_model, dtype)
        p["ssm"] = init_ssm(keys[0], cfg, dtype)
    if cross:
        p["norm_x"] = init_norm(cfg, cfg.d_model, dtype)
        p["cross"] = init_attention(keys[2], cfg, dtype)
    if entry.ffn == "ffn":
        p["norm2"] = init_norm(cfg, cfg.d_model, dtype)
        p["ffn"] = init_ffn(keys[1], cfg.d_model, cfg.ffn, cfg.n_layers, dtype,
                            ep_degree)
    return p


def init_shared_block(key, cfg: ModelConfig, dtype) -> Dict:
    """zamba2-style shared block: attention + MLP applied at many depths."""
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "norm2": init_norm(cfg, cfg.d_model, dtype),
        "ffn": init_ffn(k2, cfg.d_model, cfg.ffn, cfg.n_layers, dtype),
    }


def apply_block(params: Dict, shared: Optional[Dict], x: jax.Array,
                cfg: ModelConfig, entry: BlockSpecEntry, *,
                rng: Optional[jax.Array], train: bool,
                positions: Optional[jax.Array],
                cache: Optional[Dict], cache_index,
                memory: Optional[jax.Array] = None,
                enc_out: Optional[jax.Array] = None,
                cross_cache: Optional[Dict] = None,
                block_table: Optional[jax.Array] = None,
                seq_lens: Optional[jax.Array] = None,
                sp: bool = False) -> Tuple[jax.Array, Dict, Optional[Dict], Optional[jax.Array]]:
    """Pre-norm residual block. Returns (x, aux, new_cache, new_memory)."""
    aux = {"moe_reg": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}
    new_cache = {}
    new_memory = None
    r1 = r2 = r3 = None
    if rng is not None:
        r1, r2, r3 = jax.random.split(rng, 3)

    def constrain(h):
        return (with_logical_constraint(h, ("batch", "seq", None), SP_RULES)
                if sp else h)

    mixer_params = params
    mixer = entry.mixer
    if mixer == "shared_attn":
        mixer_params = shared
        mixer = "attn"

    if mixer == "attn":
        h = apply_norm(mixer_params["norm1"], x, cfg)
        if cfg.pos_encoding == "xl_rel" and memory is not None:
            new_memory = jax.lax.stop_gradient(
                jnp.concatenate([memory.astype(x.dtype), h], axis=1)[:, -memory.shape[1]:])
        y, c = apply_attention(mixer_params["attn"], h, cfg,
                               kind=entry.attn_kind, positions=positions,
                               cache=cache.get("self") if cache else None,
                               cache_index=cache_index, memory=memory,
                               block_table=block_table, seq_lens=seq_lens)
        if c is not None:
            new_cache["self"] = c
        x = constrain(x + dropout(r1, y, cfg.dropout, train))
    elif mixer == "ssm":
        h = apply_norm(params["norm1"], x, cfg)
        y, c = apply_ssm(params["ssm"], h, cfg,
                         cache=cache.get("ssm") if cache else None)
        if c is not None:
            new_cache["ssm"] = c
        x = constrain(x + dropout(r1, y, cfg.dropout, train))

    if "cross" in params and (enc_out is not None or cross_cache is not None):
        h = apply_norm(params["norm_x"], x, cfg)
        if cross_cache is not None:
            kv = (cross_cache["k"].astype(h.dtype), cross_cache["v"].astype(h.dtype))
            y, _ = apply_attention(params["cross"], h, cfg, positions=positions,
                                   cross_kv=kv)
            new_cache["cross"] = cross_cache      # static after prefill; pass through
        else:
            y, _ = _cross_attend(params["cross"], h, enc_out, cfg, positions)
        x = constrain(x + dropout(r3, y, cfg.dropout, train))

    ffn_kind = entry.ffn
    if ffn_kind != "none":
        fp = shared["ffn"] if ffn_kind == "shared_ffn" else params["ffn"]
        fn = shared["norm2"] if ffn_kind == "shared_ffn" else params["norm2"]
        h = apply_norm(fn, x, cfg)
        y, faux = apply_ffn(fp, h, cfg.ffn, rng=r2, train=train)
        aux = {k: aux[k] + faux.get(k, 0.0) for k in aux}
        x = constrain(x + dropout(r2, y, cfg.dropout, train))
    return x, aux, (new_cache or None), new_memory


def _cross_attend(cparams, h, enc_out, cfg, positions):
    from .attention import _split_heads
    a = cfg.attention
    k = _split_heads(jnp.einsum("bsd,dq->bsq", enc_out,
                                cparams["wk"].astype(h.dtype)), a.n_kv_heads, a.head_dim)
    v = _split_heads(jnp.einsum("bsd,dq->bsq", enc_out,
                                cparams["wv"].astype(h.dtype)), a.n_kv_heads, a.head_dim)
    return apply_attention(cparams, h, cfg, positions=positions, cross_kv=(k, v))


def cross_kv_cache(cparams, enc_out, cfg) -> Dict:
    """Precompute encoder K/V for decode (whisper prefill)."""
    from .attention import _split_heads
    a = cfg.attention
    k = _split_heads(jnp.einsum("bsd,dq->bsq", enc_out,
                                cparams["wk"].astype(enc_out.dtype)),
                     a.n_kv_heads, a.head_dim)
    v = _split_heads(jnp.einsum("bsd,dq->bsq", enc_out,
                                cparams["wv"].astype(enc_out.dtype)),
                     a.n_kv_heads, a.head_dim)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Stack init / apply
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, dtype, *, n_layers: Optional[int] = None,
               ep_degree: int = 0, cross: bool = False) -> Dict:
    segs = plan_segments(cfg, n_layers)
    key, skey = jax.random.split(key)
    params: Dict[str, Any] = {"segments": []}
    if _needs_shared(cfg):
        params["shared"] = init_shared_block(skey, cfg, dtype)
    for seg in segs:
        seg_params = {}
        for ei, entry in enumerate(seg.entries):
            key, ekey = jax.random.split(key)
            ekeys = jax.random.split(ekey, seg.repeats)
            seg_params[f"e{ei}"] = jax.vmap(
                lambda kk: init_block(kk, cfg, entry, dtype, ep_degree, cross)
            )(ekeys)
        params["segments"].append(seg_params)
    return params


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                     *, n_layers: Optional[int] = None) -> Dict:
    segs = plan_segments(cfg, n_layers)

    def entry_cache(entry):
        c = {}
        if entry.mixer in ("attn", "shared_attn"):
            c["self"] = init_attn_cache(cfg, batch, max_len, dtype)
        elif entry.mixer == "ssm":
            c["ssm"] = init_ssm_cache(cfg, batch)
        return c

    cache = {"segments": []}
    for seg in segs:
        seg_cache = {}
        for ei, entry in enumerate(seg.entries):
            ec = entry_cache(entry)
            seg_cache[f"e{ei}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (seg.repeats,) + a.shape).copy(), ec)
        cache["segments"].append(seg_cache)
    return cache


def init_paged_stack_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                           dtype, *, n_layers: Optional[int] = None) -> Dict:
    """Paged KV pools mirroring the stack structure (page 0 reserved).

    The pool shape is batch-independent: the per-request mapping lives in
    the block table threaded through ``apply_stack`` instead.
    """
    segs = plan_segments(cfg, n_layers)

    def entry_cache(entry):
        if entry.mixer in ("attn", "shared_attn"):
            return {"self": init_attn_paged_cache(cfg, n_pages, page_size,
                                                  dtype)}
        if entry.mixer == "ssm":
            raise NotImplementedError("paged cache: ssm mixers unsupported")
        return {}

    cache = {"segments": []}
    for seg in segs:
        seg_cache = {}
        for ei, entry in enumerate(seg.entries):
            ec = entry_cache(entry)
            seg_cache[f"e{ei}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (seg.repeats,) + a.shape).copy(), ec)
        cache["segments"].append(seg_cache)
    return cache


def apply_stack(params: Dict, x: jax.Array, cfg: ModelConfig, *,
                rng: Optional[jax.Array] = None, train: bool = False,
                positions: Optional[jax.Array] = None,
                cache: Optional[Dict] = None, cache_index=None,
                mems: Optional[jax.Array] = None,
                enc_out: Optional[jax.Array] = None,
                cross_caches: Optional[Dict] = None,
                block_table: Optional[jax.Array] = None,
                seq_lens: Optional[jax.Array] = None,
                remat: str = "none", sp: bool = False,
                n_layers: Optional[int] = None):
    """Run all segments. Returns (x, aux, new_cache, new_mems)."""
    segs = plan_segments(cfg, n_layers)
    shared = params.get("shared")
    aux_tot = {"moe_reg": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}
    new_cache = {"segments": []} if cache is not None else None
    new_mems_segs = [] if mems is not None else None
    layer_offset = 0

    policy = {"none": None,
              "full": jax.checkpoint_policies.nothing_saveable,
              "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable}[remat]

    for si, seg in enumerate(segs):
        seg_params = params["segments"][si]
        seg_cache = cache["segments"][si] if cache is not None else None
        seg_mems = None
        if mems is not None:
            seg_mems = mems["segments"][si]

        def body(x, xs, seg=seg, off=layer_offset):
            ep, ridx, cxs, mxs = xs
            aux_acc = {"moe_reg": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}
            new_c = {}
            new_m = {}
            for ei, entry in enumerate(seg.entries):
                li = off + ridx * len(seg.entries) + ei
                r = jax.random.fold_in(rng, li) if rng is not None else None
                mem_i = mxs.get(f"e{ei}") if mxs is not None else None
                xc, aux, nc, nm = apply_block(
                    ep[f"e{ei}"], shared, x, cfg, entry, rng=r, train=train,
                    positions=positions,
                    cache=cxs.get(f"e{ei}") if cxs is not None else None,
                    cache_index=cache_index, memory=mem_i,
                    enc_out=enc_out,
                    cross_cache=(cxs.get(f"e{ei}", {}) or {}).get("cross")
                    if cxs is not None else None,
                    block_table=block_table, seq_lens=seq_lens,
                    sp=sp)
                x = xc
                aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
                if nc is not None:
                    new_c[f"e{ei}"] = nc
                if nm is not None:
                    new_m[f"e{ei}"] = nm
            return x, (aux_acc, new_c, new_m)

        if policy is not None:
            body = jax.checkpoint(body, policy=policy)

        xs = (seg_params, jnp.arange(seg.repeats), seg_cache, seg_mems)
        if seg.repeats == 1:
            # single application: avoid scan overhead, index the stacked params
            ep0 = jax.tree_util.tree_map(lambda a: a[0], seg_params)
            c0 = (jax.tree_util.tree_map(lambda a: a[0], seg_cache)
                  if seg_cache is not None else None)
            m0 = (jax.tree_util.tree_map(lambda a: a[0], seg_mems)
                  if seg_mems is not None else None)
            x, (aux, nc, nm) = body(x, (ep0, jnp.int32(0), c0, m0))
            nc = jax.tree_util.tree_map(lambda a: a[None], nc)
            nm = jax.tree_util.tree_map(lambda a: a[None], nm)
        else:
            x, (auxs, nc, nm) = jax.lax.scan(body, x, xs)
            aux = jax.tree_util.tree_map(lambda a: jnp.sum(a, 0), auxs)
        aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
        if new_cache is not None:
            new_cache["segments"].append(nc if nc else seg_cache)
        if new_mems_segs is not None:
            new_mems_segs.append(nm)
        layer_offset += seg.repeats * len(seg.entries)

    new_mems = {"segments": new_mems_segs} if new_mems_segs is not None else None
    return x, aux_tot, new_cache, new_mems


def init_mems(cfg: ModelConfig, batch: int, dtype) -> Dict:
    """XL segment memory, mirroring the stack structure (uniform attn only)."""
    segs = plan_segments(cfg)
    out = {"segments": []}
    for seg in segs:
        seg_m = {}
        for ei, entry in enumerate(seg.entries):
            if entry.mixer == "attn":
                seg_m[f"e{ei}"] = jnp.zeros(
                    (seg.repeats, batch, cfg.xl_memory, cfg.d_model), dtype)
        out["segments"].append(seg_m)
    return out
