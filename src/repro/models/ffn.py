"""FFN registry: wires the paper's approximators (core/) into model blocks.

Any architecture can swap its FFN via ``FFNConfig.kind`` — this is exactly the
paper's thesis (the technique applies to *every* MLP block, at any scale).

``FFN_REGISTRY`` maps each kind to one ``FFNEntry(init, apply)`` with a
uniform contract instead of parallel if-chains:

    init(key, d_model, cfg, n_layers, dtype, ep_degree) -> params dict
    apply(params, x, cfg, *, rng, train, collect_stats) -> (y, aux)

where ``aux`` always carries the same keys (``moe_reg``, ``moe_dropped`` —
see core/dispatch.base_aux) plus ``usage`` (a selection-usage histogram:
experts, PKM values, or top-K channels) when ``collect_stats=True``. Model
code (stack.py) therefore sums aux uniformly with zero per-kind fabrication.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import FFNConfig
from ..core.dispatch import base_aux
from ..core.moe import apply_moe, init_moe
from ..core.pkm import apply_pkm, init_pkm
from ..core.topk_mlp import apply_dense, init_dense

MOE_KINDS = ("sigma_moe", "switch", "sbase", "noisy_topk")


class FFNEntry(NamedTuple):
    """One approximator: paired (init, apply) with the uniform contract."""
    init: Callable[..., Dict]
    apply: Callable[..., Tuple[jax.Array, Dict]]


def _init_none(key, d_model: int, cfg: FFNConfig, n_layers: int,
               dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    return {}


def _apply_none(params: Dict, x: jax.Array, cfg: FFNConfig, *,
                rng=None, train: bool = False,
                collect_stats: bool = False) -> Tuple[jax.Array, Dict]:
    return jnp.zeros_like(x), base_aux()


FFN_REGISTRY: Dict[str, FFNEntry] = {
    "dense": FFNEntry(init_dense, apply_dense),
    "glu": FFNEntry(init_dense, apply_dense),
    "topk": FFNEntry(init_dense, apply_dense),
    "pkm": FFNEntry(init_pkm, apply_pkm),
    "none": FFNEntry(_init_none, _apply_none),
    **{kind: FFNEntry(init_moe, apply_moe) for kind in MOE_KINDS},
}


def init_ffn(key, d_model: int, cfg: FFNConfig, n_layers: int,
             dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    return FFN_REGISTRY[cfg.kind].init(key, d_model, cfg, n_layers, dtype,
                                       ep_degree)


def apply_ffn(params: Dict, x: jax.Array, cfg: FFNConfig, *,
              rng: Optional[jax.Array] = None, train: bool = False,
              collect_stats: bool = False) -> Tuple[jax.Array, Dict]:
    return FFN_REGISTRY[cfg.kind].apply(params, x, cfg, rng=rng, train=train,
                                        collect_stats=collect_stats)
