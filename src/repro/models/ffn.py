"""FFN dispatcher: wires the paper's approximators (core/) into model blocks.

Any architecture can swap its FFN via ``FFNConfig.kind`` — this is exactly the
paper's thesis (the technique applies to *every* MLP block, at any scale).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import FFNConfig
from ..core.moe import apply_moe, init_moe
from ..core.pkm import apply_pkm, init_pkm
from ..core.topk_mlp import apply_dense, init_dense

MOE_KINDS = ("sigma_moe", "switch", "sbase", "noisy_topk")


def init_ffn(key, d_model: int, cfg: FFNConfig, n_layers: int,
             dtype=jnp.float32, ep_degree: int = 0) -> Dict:
    if cfg.kind == "none":
        return {}
    if cfg.kind in MOE_KINDS:
        return init_moe(key, d_model, cfg, n_layers, dtype, ep_degree)
    if cfg.kind == "pkm":
        return init_pkm(key, d_model, cfg, n_layers, dtype)
    return init_dense(key, d_model, cfg, n_layers, dtype)


def apply_ffn(params: Dict, x: jax.Array, cfg: FFNConfig, *,
              rng: Optional[jax.Array] = None, train: bool = False
              ) -> Tuple[jax.Array, Dict]:
    zero_aux = {"moe_reg": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}
    if cfg.kind == "none":
        return jnp.zeros_like(x), zero_aux
    if cfg.kind in MOE_KINDS:
        return apply_moe(params, x, cfg, rng=rng, train=train)
    if cfg.kind == "pkm":
        y, _ = apply_pkm(params, x, cfg)
        return y, zero_aux
    y, _ = apply_dense(params, x, cfg)
    return y, zero_aux
