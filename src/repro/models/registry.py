"""Model builder: arch name / ModelConfig -> LM instance."""
from __future__ import annotations

from typing import Optional, Union

from ..configs.archs import get_config
from ..configs.base import ModelConfig
from .lm import LM


def build_model(cfg_or_name: Union[str, ModelConfig], *, remat: str = "none",
                sequence_parallel: bool = False, ce_chunks: int = 0,
                ep_degree: int = 0, ffn: Optional[str] = None,
                **overrides) -> LM:
    cfg = (get_config(cfg_or_name) if isinstance(cfg_or_name, str)
           else cfg_or_name)
    if overrides:
        cfg = cfg.override(**overrides)
    if ffn is not None and ffn != cfg.ffn.kind:
        # sigma-MoE-ify (or otherwise swap) the FFN of any architecture: the paper's
        # technique as a first-class drop-in (parameter-matched G*N_E = d_ff).
        from ..configs.base import FFNConfig, moe_ffn
        d_ff = cfg.ffn.d_ff or 4 * cfg.d_model
        if ffn == "sigma_moe":
            g = 128 if d_ff % 128 == 0 else max(64, d_ff // 16)
            ne = max(2, d_ff // g)
            cfg = cfg.with_ffn(moe_ffn(ne, g, max(1, min(4, ne // 2)),
                                       glu_experts=cfg.ffn.kind == "glu",
                                       reg_gamma=1e-3, reg_kind="entropy"))
        elif ffn == "topk":
            cfg = cfg.with_ffn(FFNConfig(kind="topk", d_ff=d_ff,
                                         topk_k=max(64, d_ff // 8)))
        elif ffn == "pkm":
            ns = max(4, int(d_ff ** 0.5))
            # each half produces only n_subkeys scores, so K (and hence the
            # candidate count C, which defaults to K) must clamp to it on
            # reduced configs; production archs have ns >= 32 and keep K=32.
            knn = min(FFNConfig.pkm_knn, ns)
            cfg = cfg.with_ffn(FFNConfig(kind="pkm", n_subkeys=ns, pkm_knn=knn))
        elif ffn in ("dense", "glu"):
            cfg = cfg.with_ffn(FFNConfig(kind=ffn, d_ff=d_ff,
                                         activation=cfg.ffn.activation or "relu"))
        else:
            raise ValueError(f"cannot swap ffn to {ffn}")
    return LM(cfg, remat=remat, sequence_parallel=sequence_parallel,
              ce_chunks=ce_chunks, ep_degree=ep_degree)
