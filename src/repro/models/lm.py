"""Unified language model: decoder-only (dense / MoE / SSM / hybrid / VLM) and
encoder-decoder (whisper) in one functional class.

Public step surface (consumed by runtime/ and launch/):
    init(key) -> params
    loss(params, batch, rng, train) -> (loss, metrics)          [train_4k]
    prefill(params, batch) -> (last_logits, cache)               [prefill_32k]
    decode_step(params, cache, token, pos) -> (logits, cache)    [decode_32k/long_500k]
    init_cache(batch_size, max_len) -> cache
    input_specs(shape) / state_specs(shape) -> ShapeDtypeStructs for the dry-run
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import BlockSpecEntry, ModelConfig, ShapeConfig
from ..sharding.logical import SP_RULES, with_logical_constraint
from .layers import apply_norm, dropout, init_embedding, init_norm
from .stack import (apply_stack, cross_kv_cache, init_paged_stack_cache,
                    init_stack, init_stack_cache, plan_segments)


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


class LM:
    def __init__(self, cfg: ModelConfig, *, remat: str = "none",
                 sequence_parallel: bool = False, ce_chunks: int = 0,
                 ep_degree: int = 0):
        self.cfg = cfg
        self.remat = remat
        self.sp = sequence_parallel
        self.ep_degree = ep_degree
        # auto chunked-CE: bound the (tokens x vocab) logits buffer
        self.ce_chunks = ce_chunks
        self.dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)
        # vocab padded to a TP-friendly multiple (MaxText-style); padded logit
        # columns are masked to -inf everywhere they can leak out.
        from ..common import round_up
        self.vocab_padded = round_up(cfg.vocab_size, 512)

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, 6)
        p: Dict[str, Any] = {
            "emb": init_embedding(keys[0], self.vocab_padded, cfg.d_model,
                                  self.param_dtype),
            "final_norm": init_norm(cfg, cfg.d_model, self.param_dtype),
            "stack": init_stack(keys[1], cfg, self.param_dtype,
                                ep_degree=self.ep_degree,
                                cross=cfg.is_encoder_decoder),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = init_embedding(keys[2], cfg.d_model, self.vocab_padded,
                                          self.param_dtype) * (cfg.d_model ** -0.5)
        if cfg.pos_encoding == "learned":
            p["pos_emb"] = 0.01 * jax.random.normal(
                keys[3], (cfg.max_seq_len, cfg.d_model), self.param_dtype)
        if cfg.is_encoder_decoder:
            enc_cfg = self._encoder_cfg()
            p["enc_stack"] = init_stack(keys[4], enc_cfg, self.param_dtype,
                                        n_layers=cfg.n_encoder_layers)
            p["enc_norm"] = init_norm(cfg, cfg.d_model, self.param_dtype)
            p["enc_pos"] = 0.01 * jax.random.normal(
                keys[5], (cfg.n_audio_frames, cfg.d_model), self.param_dtype)
        return p

    def _encoder_cfg(self) -> ModelConfig:
        return self.cfg.override(
            pattern=(BlockSpecEntry(mixer="attn", ffn="ffn",
                                    attn_kind="noncausal"),),
            pos_encoding="learned")

    # -------------------------------------------------------------- embedding
    def _embed(self, params, tokens, *, prefix_embeds=None, pos_offset=0):
        cfg = self.cfg
        x = params["emb"].astype(self.dtype)[tokens]
        if cfg.pos_encoding == "learned":
            s = tokens.shape[1]
            pe = jax.lax.dynamic_slice_in_dim(
                params["pos_emb"].astype(self.dtype), pos_offset, s, axis=0)
            x = x + pe[None]
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(self.dtype), x], axis=1)
        return x

    def _unembed(self, params, h):
        cfg = self.cfg
        w = (params["emb"].T if cfg.tie_embeddings else params["unembed"])
        logits = jnp.einsum("...d,dv->...v", h, w.astype(h.dtype))
        logits = _softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        if self.vocab_padded != cfg.vocab_size:
            valid = jnp.arange(self.vocab_padded) < cfg.vocab_size
            logits = jnp.where(valid, logits, -1e30)
        return logits

    def _encode(self, params, frames, *, rng=None, train=False):
        cfg = self.cfg
        x = frames.astype(self.dtype) + params["enc_pos"].astype(self.dtype)[None]
        x, aux, _, _ = apply_stack(params["enc_stack"], x, self._encoder_cfg(),
                                   rng=rng, train=train, remat=self.remat,
                                   sp=self.sp, n_layers=cfg.n_encoder_layers)
        return apply_norm(params["enc_norm"], x, cfg), aux

    # ------------------------------------------------------------------ train
    def forward(self, params, tokens, *, prefix_embeds=None, frames=None,
                rng=None, train=False, mems=None):
        """Full-sequence forward -> (hidden, aux, new_mems)."""
        cfg = self.cfg
        r_emb = r_stack = None
        if rng is not None:
            r_emb, r_stack = jax.random.split(rng)
        x = self._embed(params, tokens, prefix_embeds=prefix_embeds)
        x = dropout(r_emb, x, cfg.dropout, train)
        x = (with_logical_constraint(x, ("batch", "seq", None), SP_RULES)
             if self.sp else with_logical_constraint(x, ("batch", None, None)))
        enc_out = None
        aux_e = {}
        if cfg.is_encoder_decoder:
            enc_out, aux_e = self._encode(params, frames, rng=rng, train=train)
        positions = jnp.arange(x.shape[1])
        x, aux, _, new_mems = apply_stack(
            params["stack"], x, cfg, rng=r_stack, train=train,
            positions=positions, mems=mems, enc_out=enc_out,
            remat=self.remat, sp=self.sp)
        if aux_e:
            aux = {k: aux[k] + aux_e.get(k, 0.0) for k in aux}
        x = apply_norm(params["final_norm"], x, cfg)
        return x, aux, new_mems

    def loss(self, params, batch: Dict, rng=None, train: bool = True,
             mems=None) -> Tuple[jax.Array, Dict]:
        """Next-token CE (+ MoE regularizers). batch: tokens (B,S) [, frames/patches].

        Vision prefix tokens are unsupervised; labels are tokens shifted by one.
        """
        from ..runtime.loss import chunked_cross_entropy
        cfg = self.cfg
        tokens = batch["tokens"]
        prefix = batch.get("patches")
        h, aux, new_mems = self.forward(
            params, tokens, prefix_embeds=prefix, frames=batch.get("frames"),
            rng=rng, train=train, mems=mems)
        n_prefix = prefix.shape[1] if prefix is not None else 0
        h_text = h[:, n_prefix:, :]
        w = (params["emb"].T if cfg.tie_embeddings else params["unembed"])
        ce, n_tok = chunked_cross_entropy(
            h_text[:, :-1], w.astype(h_text.dtype), tokens[:, 1:],
            chunks=self.ce_chunks, softcap=cfg.logit_softcap,
            n_valid_vocab=(cfg.vocab_size
                           if self.vocab_padded != cfg.vocab_size else 0))
        loss = ce + aux["moe_reg"]
        metrics = {"ce": ce, "moe_reg": aux["moe_reg"],
                   "moe_dropped": aux["moe_dropped"], "tokens": n_tok}
        return loss, (metrics if mems is None else (metrics, new_mems))

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int) -> Dict:
        return init_stack_cache(self.cfg, batch, max_len, self.dtype)

    def prefill(self, params, batch: Dict, cache: Dict) -> Tuple[jax.Array, Dict]:
        """Run the prompt through the stack, filling `cache`; returns last logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, prefix_embeds=batch.get("patches"))
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out, _ = self._encode(params, batch["frames"])
            cache = self._attach_cross_caches(params, cache, enc_out)
        positions = jnp.arange(x.shape[1])
        x, _, new_cache, _ = apply_stack(
            params["stack"], x, cfg, positions=positions, cache=cache,
            cache_index=jnp.int32(0), enc_out=None, remat=self.remat, sp=self.sp)
        x = apply_norm(params["final_norm"], x[:, -1:, :], cfg)
        return self._unembed(params, x)[:, 0], new_cache

    def _attach_cross_caches(self, params, cache, enc_out):
        """Precompute per-decoder-layer cross K/V (whisper)."""
        segs = plan_segments(self.cfg)
        new_cache = {"segments": []}
        for si, seg in enumerate(segs):
            seg_params = params["stack"]["segments"][si]
            seg_cache = dict(cache["segments"][si])
            for ei, entry in enumerate(seg.entries):
                stacked = seg_params[f"e{ei}"]
                if "cross" not in stacked:
                    continue
                cross = jax.vmap(
                    lambda cp: cross_kv_cache(cp, enc_out, self.cfg))(stacked["cross"])
                ec = dict(seg_cache[f"e{ei}"])
                ec["cross"] = cross
                seg_cache[f"e{ei}"] = ec
            new_cache["segments"].append(seg_cache)
        return new_cache

    # --------------------------------------------------------- paged serving
    def _check_paged_support(self) -> None:
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "paged serving: encoder-decoder models unsupported")
        if cfg.pos_encoding not in ("rope", "none"):
            raise NotImplementedError(
                f"paged serving: pos_encoding={cfg.pos_encoding!r} unsupported"
                " (per-request offsets need position-free embeddings)")
        if cfg.n_vision_tokens:
            raise NotImplementedError("paged serving: vision prefix unsupported")

    def init_paged_cache(self, n_pages: int, page_size: int) -> Dict:
        """Paged KV pool shared by all requests; page 0 is the reserved
        null/scratch page (never handed out by the allocator). The pool shape
        is batch-independent: per-request placement lives in block tables."""
        self._check_paged_support()
        return init_paged_stack_cache(self.cfg, n_pages, page_size, self.dtype)

    def prefill_paged(self, params, tokens: jax.Array, cache: Dict,
                      block_table: jax.Array, start, length
                      ) -> Tuple[jax.Array, Dict]:
        """Prefill ONE request's chunk into the paged pool.

        tokens (1, S) fixed-size padded chunk, block_table (1, n_blocks),
        start = absolute offset of this chunk in the request, length = number
        of valid tokens in the chunk (<= S; the padded tail is dropped on the
        reserved OOB page). Returns (logits at the last valid token (1, V),
        new_cache).
        """
        cfg = self.cfg
        x = self._embed(params, tokens)
        positions = start + jnp.arange(tokens.shape[1])
        seq_lens = jnp.asarray(length, jnp.int32).reshape(1)
        x, _, new_cache, _ = apply_stack(
            params["stack"], x, cfg, positions=positions, cache=cache,
            cache_index=start, block_table=block_table, seq_lens=seq_lens,
            sp=False)
        last = jax.lax.dynamic_slice_in_dim(
            x, jnp.maximum(jnp.asarray(length, jnp.int32) - 1, 0), 1, axis=1)
        last = apply_norm(params["final_norm"], last, cfg)
        return self._unembed(params, last)[:, 0], new_cache

    def decode_step_paged(self, params, cache: Dict, token: jax.Array,
                          positions: jax.Array, block_tables: jax.Array
                          ) -> Tuple[jax.Array, Dict]:
        """One batched paged decode step. token (B,), positions (B,) absolute
        per-request positions, block_tables (B, n_blocks)."""
        cfg = self.cfg
        x = self._embed(params, token[:, None])
        x, _, new_cache, _ = apply_stack(
            params["stack"], x, cfg, positions=positions[:, None], cache=cache,
            cache_index=positions, block_table=block_tables, sp=False)
        x = apply_norm(params["final_norm"], x, cfg)
        return self._unembed(params, x)[:, 0], new_cache

    def decode_step(self, params, cache: Dict, token: jax.Array,
                    pos) -> Tuple[jax.Array, Dict]:
        """One batched decode step. token (B,), pos scalar int32."""
        cfg = self.cfg
        x = self._embed(params, token[:, None], pos_offset=pos)
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
        x, _, new_cache, _ = apply_stack(
            params["stack"], x, cfg, positions=positions, cache=cache,
            cache_index=pos, sp=False)
        x = apply_norm(params["final_norm"], x, cfg)
        return self._unembed(params, x)[:, 0], new_cache

    # ----------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input (dry-run; no allocation)."""
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs: Dict[str, Any] = {}
        if shape.mode in ("train", "prefill"):
            n_vis = cfg.n_vision_tokens
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - n_vis), jnp.int32)
            if n_vis:
                specs["patches"] = jax.ShapeDtypeStruct((b, n_vis, cfg.d_model),
                                                        self.dtype)
            if cfg.is_encoder_decoder:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_audio_frames, cfg.d_model), self.dtype)
        else:  # decode
            specs["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        return specs
