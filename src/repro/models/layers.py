"""Common neural layers: norms, embeddings, rotary, positional encodings."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int, dtype=jnp.float32) -> Dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_simple(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * (d ** -0.5)


def sinusoid_positions(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Classic sinusoidal table (used by XL relative encodings and whisper)."""
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    pos = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[..., None] * freqs    # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dropout(rng: Optional[jax.Array], x: jax.Array, rate: float,
            train: bool) -> jax.Array:
    if not train or rate <= 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0).astype(x.dtype)
