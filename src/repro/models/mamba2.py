"""Mamba2 / SSD (state-space duality) block — chunked parallel form for
training/prefill and O(1) recurrence for decode (arXiv:2405.21060).

Chunked SSD: split the sequence into chunks of length Q. Within a chunk the output is
an attention-like quadratic form masked by the decay kernel; across chunks a small
(H, P, N) state is carried by an (associative) scan. Both paths are pure jax.lax, so
they lower cleanly under pjit at 500k tokens (the long_500k shape).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import rms_norm_simple


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = din + 2 * s.n_groups * s.d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * din + 2 * s.n_groups * s.d_state + nh    # z, x, B, C, dt
    return {
        "in_proj": (d ** -0.5) * jax.random.normal(k1, (d, in_dim), dtype),
        "conv_w": 0.1 * jax.random.normal(k2, (conv_dim, s.d_conv), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(dtype)),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (nh,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))).astype(dtype),
        "scale": jnp.ones((din,), dtype),                 # gated RMSNorm
        "out_proj": (din ** -0.5) * jax.random.normal(k4, (din, d), dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) lower-triangular pairwise cumulative sums:
    out[i, j] = a[j+1] + ... + a[i] for i >= j, -inf above the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. x (b,s,h,p), dt (b,s,h) >=0, A (h,)<0, B/C (b,s,g,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk
    # chunked views: (b, nc, Q, ...)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                       # broadcast groups->heads
    Ch = jnp.repeat(Cc, rep, axis=3)

    a = (dtc.astype(jnp.float32) * A.astype(jnp.float32))  # (b,nc,Q,h) decay logs
    a = jnp.moveaxis(a, -1, -2)                            # (b,nc,h,Q)
    a_cum = jnp.cumsum(a, axis=-1)                         # within-chunk cumsum

    # 1) intra-chunk (quadratic within chunk, like masked attention)
    L = jnp.exp(_segsum(a))                                # (b,nc,h,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bhcqk", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))
    Lt = jnp.moveaxis(L, 2, 1)                             # (b,h,nc,Q,Q)
    xdt = xc.astype(jnp.float32) * dtc.astype(jnp.float32)[..., None]
    y_diag = jnp.einsum("bhcqk,bckhp->bcqhp", scores * Lt, xdt)

    # 2) chunk states: state_c = sum_k decay_to_end[k] * B_k (dt_k x_k)^T
    decay_end = jnp.exp(a_cum[..., -1:] - a_cum)           # (b,nc,h,Q)
    de = decay_end.transpose(0, 1, 3, 2)                   # (b,nc,Q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bh.astype(jnp.float32), de, xdt)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[..., -1])                  # (b,nc,h)

    def scan_body(carry, xs):
        st_prev = carry                                    # (b,h,p,n)
        st_c, dec_c = xs                                   # (b,h,p,n), (b,h)
        st = st_c + dec_c[..., None, None] * st_prev
        return st, st_prev

    st0 = (init_state.astype(jnp.float32) if init_state is not None
           else jnp.zeros((b, h, p, n), jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_body, st0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b,nc,h,p,n)

    # 4) inter-chunk contribution: y_off = C_t . (decay_from_start_t * state_prev)
    decay_start = jnp.exp(a_cum)                           # (b,nc,h,Q) decay 0..t
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Ch.astype(jnp.float32),
                       prev_states, decay_start)

    y = (y_diag + y_off).reshape(b, nc * chunk, h, p)[:, :s]
    return y, final_state


def ssd_decode_step(x, dt, A, B, C, state):
    """One-token recurrence: state (b,h,p,n) -> (y (b,h,p), new_state)."""
    dec = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))      # (b,h)
    upd = jnp.einsum("bhn,bhp->bhpn", B.astype(jnp.float32),
                     (x * dt[..., None]).astype(jnp.float32))
    new_state = dec[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C.astype(jnp.float32))
    return y, new_state


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                   cache: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x (B,S,C), w (C,K). Returns (y, new_cache (B,K-1,C))."""
    k = w.shape[-1]
    if cache is None:
        prefix = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        prefix = cache.astype(x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    new_cache = xp[:, -(k - 1):, :]
    windows = [xp[:, i:i + x.shape[1], :] for i in range(k)]
    y = sum(windows[i] * w[:, i] for i in range(k)) + b
    return y, new_cache


def apply_ssm(params: Dict, x: jax.Array, cfg: ModelConfig, *,
              cache: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """Full mamba2 block. cache = {"conv": (B,K-1,C), "state": (B,H,P,N)} for decode."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    din = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    g, n = s_cfg.n_groups, s_cfg.d_state

    proj = jnp.einsum("bsd,di->bsi", x, params["in_proj"].astype(x.dtype))
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + g * n, 2 * din + 2 * g * n], axis=-1)

    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _conv1d_causal(conv_in, params["conv_w"].astype(x.dtype),
                                        params["conv_b"].astype(x.dtype),
                                        cache["conv"] if cache else None)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [din, din + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, s, nh, s_cfg.head_dim)
    Bh = Bc.reshape(b, s, g, n)
    Ch = Cc.reshape(b, s, g, n)

    new_cache = None
    if cache is not None and s == 1:
        rep = nh // g
        Bh1 = jnp.repeat(Bh[:, 0], rep, axis=1)            # (b, h, n)
        Ch1 = jnp.repeat(Ch[:, 0], rep, axis=1)
        y1, new_state = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bh1, Ch1,
                                        cache["state"])
        y = y1[:, None]
        new_cache = {"conv": new_conv, "state": new_state}
    else:
        y, final_state = ssd_chunked(xh, dt, A, Bh, Ch, s_cfg.chunk,
                                     cache["state"] if cache else None)
        if cache is not None:
            new_cache = {"conv": new_conv, "state": final_state}

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm_simple(y, params["scale"])
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(x.dtype)), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    conv_dim = din + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.d_state), jnp.float32),
    }
