"""Small shared utilities (no device state touched at import)."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_to_multiple(x: jax.Array, multiple: int, axis: int):
    """Zero-pad `axis` of x up to a multiple. Returns (padded, original_size)."""
    size = x.shape[axis]
    pad = round_up(size, multiple) - size
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {
        "relu": jax.nn.relu,
        "gelu": functools.partial(jax.nn.gelu, approximate=True),
        "silu": jax.nn.silu,
        "softmax": lambda x: jax.nn.softmax(x, axis=-1),
        "identity": lambda x: x,
    }[name]


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
