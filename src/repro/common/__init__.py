from .utils import (act_fn, cdiv, count_params, pad_to_multiple, round_up,
                    tree_bytes, tree_cast)

__all__ = ["act_fn", "cdiv", "count_params", "pad_to_multiple", "round_up",
           "tree_bytes", "tree_cast"]
