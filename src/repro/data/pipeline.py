"""Data pipeline: deterministic synthetic LM streams and byte-level text corpora,
behind a sharding-aware, *checkpointable* iterator.

Determinism/elasticity contract: the global batch for step t is a pure function of
(seed, t). Each host materializes only its shard (host_slice), so restarts and
elastic re-sharding reproduce the exact token stream -- the property fault-tolerant
training needs (resume mid-epoch without data skew).

Synthetic stream: a mixture of Zipf-distributed unigrams and a copy/induction task
(repeat a random prefix) so that models have learnable structure (loss decreases
measurably within tens of steps -- used by the integration tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DatasetSpec:
    kind: str                  # "synthetic" | "text"
    vocab_size: int
    data: Optional[np.ndarray] = None      # token ids for kind="text"


def make_dataset(source: str, vocab_size: int) -> DatasetSpec:
    if source == "synthetic":
        return DatasetSpec(kind="synthetic", vocab_size=vocab_size)
    # byte/char-level corpus from a local file (enwik8-style)
    raw = np.frombuffer(open(source, "rb").read(), dtype=np.uint8)
    vocab = int(raw.max()) + 1
    return DatasetSpec(kind="text", vocab_size=max(vocab, vocab_size),
                       data=raw.astype(np.int32))


class DataIterator:
    """Stateful, checkpointable iterator producing (tokens,) batches.

    state = {"step": int}; `restore(state)` resumes the exact stream.
    """

    def __init__(self, spec: DatasetSpec, global_batch: int, seq_len: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1):
        self.spec = spec
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        assert global_batch % host_count == 0
        self.local_batch = global_batch // host_count
        self.step = 0

    # ------------------------------------------------------------------ state
    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state.get("seed", self.seed))

    # ------------------------------------------------------------------ batch
    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def _synthetic_batch(self, step: int) -> np.ndarray:
        rng = self._rng_for(step)
        v = self.spec.vocab_size
        b, s = self.global_batch, self.seq_len
        # Zipf unigrams
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(b, s), p=probs)
        # induction structure: copy a window so next-token prediction is learnable
        half = s // 2
        if half > 1:
            toks[:, half:half * 2] = toks[:, :half]
        return toks.astype(np.int32)

    def _text_batch(self, step: int) -> np.ndarray:
        rng = self._rng_for(step)
        data = self.spec.data
        b, s = self.global_batch, self.seq_len
        starts = rng.integers(0, len(data) - s - 1, size=(b,))
        return np.stack([data[st:st + s] for st in starts]).astype(np.int32)

    def next(self) -> Dict[str, np.ndarray]:
        full = (self._synthetic_batch(self.step) if self.spec.kind == "synthetic"
                else self._text_batch(self.step))
        lo = self.host_index * self.local_batch
        batch = {"tokens": full[lo:lo + self.local_batch]}
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()
