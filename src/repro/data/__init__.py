from .pipeline import DataIterator, make_dataset

__all__ = ["DataIterator", "make_dataset"]
