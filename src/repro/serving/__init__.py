"""Continuous-batching serving: paged KV cache, prefill/decode
disaggregation, and cached decode-shaped CVMM plans.

Block-table / KV-page contract (shared by models/attention.py:paged_attend,
models/stack.py:init_paged_stack_cache and serving/kv_cache.py):

* The per-layer cache is a POOL ``{"k": (P, page_size, KV, D), "v": ...}``
  of P fixed-size pages shared by all requests. The pool shape is
  batch-independent: join/evict never reshapes device state.
* Page 0 is the reserved null/scratch page. The allocator never hands it
  out; unallocated block-table entries point at it; dead/padding decode
  lanes scatter into it; its contents are garbage that per-lane ``kv_len``
  masking keeps out of every softmax.
* A block table row ``(n_blocks,)`` maps a request's logical page j (token
  positions ``[j*page_size, (j+1)*page_size)``) to a physical page id. ONE
  table is shared by all layers — each layer's pool is indexed with the
  same row.
* Decode writes one token at ``(table[pos // page_size], pos % page_size)``
  per lane; prefill chunks write one request (B == 1) at a time, with the
  padded chunk tail targeting the out-of-bounds page id P so those writes
  DROP.

Decode plan-cache keying (serving/decode_plan.py):

* skeleton cache: ``(n_tokens, k, n_experts, d_model, expert_size, dtype)``
  -> routing-free ``DecodePlan`` (static tile layout + dedup token gather).
  Keys are trace-time shape constants, so at steady state the jit cache and
  this cache miss together or not at all: ``rebuilds`` stays frozen.
* assembled cache: skeleton key + raw ``(idx, gates)`` bytes -> full
  ``CvmmPlan``; a routing change is an invalidation by construction. Only
  the bench/tests materialize these — the hot path runs off the skeleton.
"""
from .decode_plan import DecodePlanCache, make_provider
from .engine import Engine, Request
from .kv_cache import PagedKVCache
from .scheduler import FifoScheduler, capture_sizes, pick_capture

__all__ = ["DecodePlanCache", "Engine", "FifoScheduler", "PagedKVCache",
           "Request", "capture_sizes", "make_provider", "pick_capture"]
