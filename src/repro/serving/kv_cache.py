"""Host-side page allocator for the paged KV pool.

The device side (models/attention.py:paged_attend) only sees block tables;
this class owns which physical page belongs to which request. Page 0 is the
reserved null/scratch page: it is never handed out, every unallocated block
table entry points at it, and dead/padding decode lanes scatter into it.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class PagedKVCache:
    """Free-list page allocator over a pool of ``n_pages`` fixed-size pages.

    Pages are recycled LIFO so a drained-then-refilled engine reuses hot
    pages instead of sweeping the pool.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list, page 0 excluded (reserved null/scratch page).
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._owned: Dict[object, List[int]] = {}

    # ------------------------------------------------------------- capacity
    def pages_needed(self, total_len: int) -> int:
        return -(-total_len // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_alloc(self, total_len: int) -> bool:
        return self.pages_needed(total_len) <= len(self._free)

    # ------------------------------------------------------------ alloc/free
    def alloc(self, rid, total_len: int) -> List[int]:
        """Reserve pages covering ``total_len`` positions for request ``rid``."""
        if rid in self._owned:
            raise KeyError(f"request {rid!r} already holds pages")
        need = self.pages_needed(total_len)
        if need > len(self._free):
            raise MemoryError(
                f"paged KV pool exhausted: need {need}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(need)]
        self._owned[rid] = pages
        return list(pages)

    def free(self, rid) -> None:
        """Return ``rid``'s pages to the free list (LIFO reuse)."""
        self._free.extend(reversed(self._owned.pop(rid)))

    # ------------------------------------------------------------ block table
    def block_table(self, rid, n_blocks: int) -> np.ndarray:
        """(n_blocks,) int32 table; entries past the allocation map to the
        reserved page 0."""
        pages = self._owned[rid]
        if len(pages) > n_blocks:
            raise ValueError(
                f"request {rid!r} holds {len(pages)} pages > table width "
                f"{n_blocks}")
        t = np.zeros((n_blocks,), np.int32)
        t[:len(pages)] = pages
        return t
