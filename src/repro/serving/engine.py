"""Continuous-batching decode engine over the paged KV cache.

Requests join and leave the decode batch mid-flight (continuous batching);
prefill and decode are disaggregated — each scheduling iteration runs at
most a bounded number of prefill chunks before the decode batch steps
again, so a long prompt can never stall in-flight generation for its full
length.

The decode loop is free of per-step host syncs: a jitted ``lax.scan``
burst advances every lane ``burst_steps`` tokens with EOS/length
termination decided on device (dead lanes emit -1 and freeze), and the
host performs ONE readback per burst to harvest tokens and retire
finished lanes. Burst batch shapes are rounded up to a small capture-size
menu (powers of two) so join/evict churn never retraces.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..core import dispatch
from .decode_plan import DecodePlanCache, make_provider
from .kv_cache import PagedKVCache
from .scheduler import FifoScheduler, capture_sizes, pick_capture


@dataclass
class Request:
    rid: Any
    prompt: Sequence[int]
    max_new: int
    eos: int = -1          # token id that stops generation; -1 = never


@dataclass
class _Lane:
    """Host-authoritative state of one in-flight decode lane."""
    rid: Any
    table: np.ndarray      # (n_blocks,) int32 physical page ids
    tok: int               # last emitted token (next step's input)
    pos: int               # absolute write position of `tok`
    rem: int               # tokens still allowed
    eos: int
    out: List[int] = field(default_factory=list)


@dataclass
class _Prefill:
    """A request mid-prefill (chunks consumed across iterations)."""
    req: Request
    table: np.ndarray
    start: int = 0
    logits: Optional[jax.Array] = None   # last chunk's final-token logits


class Engine:
    """Greedy-decoding continuous-batching engine.

    Usage::

        eng = Engine(lm, params, max_batch=8, max_len=256)
        try:
            outputs = eng.run([Request("a", [3, 5, 7], max_new=16)])
        finally:
            eng.close()

    ``outputs[rid]`` is the list of generated token ids (prompt excluded).
    """

    def __init__(self, lm, params, *, max_batch: int = 8, max_len: int = 256,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 burst_steps: int = 8, prefill_chunk: int = 16,
                 prefill_chunks_per_step: int = 2,
                 use_decode_plans: bool = True,
                 decode_plan_max_tokens: Optional[int] = None):
        self.lm = lm
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.page_size = page_size
        self.n_blocks = -(-max_len // page_size)
        if n_pages is None:
            n_pages = 1 + max_batch * self.n_blocks
        self.burst_steps = burst_steps
        self.prefill_chunk = prefill_chunk
        self.prefill_chunks_per_step = prefill_chunks_per_step
        self.capture_sizes = capture_sizes(max_batch)

        self.cache = lm.init_paged_cache(n_pages, page_size)
        self.kv = PagedKVCache(n_pages, page_size)
        self.sched = FifoScheduler()
        self.lanes: List[_Lane] = []
        self.outputs: Dict[Any, List[int]] = {}
        self._partial: Optional[_Prefill] = None
        self.stats = {"prefill_chunks": 0, "decode_steps": 0, "bursts": 0,
                      "completed": 0, "evicted": 0}

        self._prefill_fn = jax.jit(self.lm.prefill_paged, donate_argnums=(2,))
        self._burst_fns: Dict[Tuple[int, int], Any] = {}

        self.plan_cache: Optional[DecodePlanCache] = None
        if use_decode_plans:
            self.plan_cache = DecodePlanCache()
            cap = (decode_plan_max_tokens if decode_plan_max_tokens is not None
                   else max(max_batch, prefill_chunk))
            dispatch.set_decode_provider(
                make_provider(self.plan_cache, max_tokens=cap))

    # --------------------------------------------------------------- teardown
    def close(self) -> None:
        if self.plan_cache is not None:
            dispatch.set_decode_provider(None)
            self.plan_cache = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if not len(req.prompt):
            raise ValueError("empty prompt")
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid!r}: prompt+max_new "
                f"{len(req.prompt) + req.max_new} exceeds max_len "
                f"{self.max_len}")
        self.sched.submit(req)

    def cancel(self, rid) -> bool:
        """Evict an in-flight request; its partial output is kept."""
        for i, lane in enumerate(self.lanes):
            if lane.rid == rid:
                self.lanes.pop(i)
                self.kv.free(rid)
                self.outputs[rid] = lane.out
                self.stats["evicted"] += 1
                return True
        return False

    def has_work(self) -> bool:
        return bool(self.lanes or self.sched or self._partial is not None)

    # ---------------------------------------------------------------- prefill
    def _prefill_one_chunk(self) -> None:
        p = self._partial
        prompt = np.asarray(p.req.prompt, np.int32)
        ln = min(self.prefill_chunk, len(prompt) - p.start)
        chunk = np.zeros((1, self.prefill_chunk), np.int32)
        chunk[0, :ln] = prompt[p.start:p.start + ln]
        p.logits, self.cache = self._prefill_fn(
            self.params, jnp.asarray(chunk), self.cache,
            jnp.asarray(p.table[None]), jnp.int32(p.start), jnp.int32(ln))
        p.start += ln
        self.stats["prefill_chunks"] += 1

    def _finish_prefill(self) -> None:
        p, self._partial = self._partial, None
        req = p.req
        t0 = int(np.argmax(jax.device_get(p.logits)[0]))
        if t0 == req.eos or req.max_new <= 1:
            # EOS at step 0 (or single-token budget): completes without
            # ever joining the decode batch.
            self.outputs[req.rid] = [t0]
            self.kv.free(req.rid)
            self.stats["completed"] += 1
            return
        self.lanes.append(_Lane(rid=req.rid, table=p.table, tok=t0,
                                pos=len(req.prompt), rem=req.max_new - 1,
                                eos=req.eos, out=[t0]))

    def _admit(self) -> bool:
        """Start prefilling the next queued request if a lane and pages are
        available. Returns False on backpressure or an empty queue."""
        if self._partial is not None:
            return True
        if not self.sched or len(self.lanes) >= self.max_batch:
            return False
        req = self.sched.peek()
        total = len(req.prompt) + req.max_new
        if not self.kv.can_alloc(total):
            return False          # backpressure: wait for lanes to retire
        self.sched.pop()
        self.kv.alloc(req.rid, total)
        self._partial = _Prefill(req=req,
                                 table=self.kv.block_table(req.rid,
                                                           self.n_blocks))
        return True

    # ----------------------------------------------------------------- decode
    def _make_burst(self, cap: int, steps: int):
        lm = self.lm

        def burst(params, cache, tok, pos, rem, live, eos, tables):
            def step(carry, _):
                cache, tok, pos, rem, live = carry
                logits, cache = lm.decode_step_paged(params, cache, tok, pos,
                                                     tables)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                emit = jnp.where(live, nxt, -1)
                rem2 = rem - live.astype(jnp.int32)
                done_now = live & ((nxt == eos) | (rem2 <= 0))
                live2 = live & ~done_now
                pos2 = pos + live.astype(jnp.int32)
                tok2 = jnp.where(live2, nxt, tok)
                return (cache, tok2, pos2, rem2, live2), emit

            carry, emitted = jax.lax.scan(step, (cache, tok, pos, rem, live),
                                          None, length=steps)
            cache, tok, pos, rem, live = carry
            return cache, live, emitted

        return jax.jit(burst, donate_argnums=(1,))

    def decode_burst(self, steps: Optional[int] = None) -> int:
        """Advance every live lane up to ``steps`` tokens; retire finished
        lanes. Returns the number of tokens harvested."""
        if not self.lanes:
            return 0
        steps = self.burst_steps if steps is None else steps
        n = len(self.lanes)
        cap = pick_capture(n, self.capture_sizes)

        tok = np.zeros((cap,), np.int32)
        pos = np.zeros((cap,), np.int32)
        rem = np.zeros((cap,), np.int32)
        live = np.zeros((cap,), bool)
        eos = np.full((cap,), -1, np.int32)
        tables = np.zeros((cap, self.n_blocks), np.int32)
        for i, lane in enumerate(self.lanes):
            tok[i], pos[i], rem[i] = lane.tok, lane.pos, lane.rem
            live[i], eos[i], tables[i] = True, lane.eos, lane.table

        fn = self._burst_fns.get((cap, steps))
        if fn is None:
            fn = self._burst_fns[(cap, steps)] = self._make_burst(cap, steps)
        self.cache, live_f, emitted = fn(
            self.params, self.cache, jnp.asarray(tok), jnp.asarray(pos),
            jnp.asarray(rem), jnp.asarray(live), jnp.asarray(eos),
            jnp.asarray(tables))
        # the ONE host readback for these `steps` decode steps
        live_f, emitted = jax.device_get((live_f, emitted))

        harvested = 0
        survivors: List[_Lane] = []
        for i, lane in enumerate(self.lanes):
            toks = emitted[:, i]
            toks = toks[toks >= 0]
            lane.out.extend(int(t) for t in toks)
            harvested += len(toks)
            if live_f[i]:
                lane.tok = int(toks[-1])
                lane.pos += len(toks)
                lane.rem -= len(toks)
                survivors.append(lane)
            else:
                self.outputs[lane.rid] = lane.out
                self.kv.free(lane.rid)
                self.stats["completed"] += 1
        self.lanes = survivors
        self.stats["decode_steps"] += steps
        self.stats["bursts"] += 1
        return harvested

    # ------------------------------------------------------------------ drive
    def step(self) -> None:
        """One scheduling iteration: a bounded number of prefill chunks
        (disaggregation — decode never waits for a whole prompt), then one
        decode burst."""
        budget = self.prefill_chunks_per_step
        while budget > 0 and self._admit():
            self._prefill_one_chunk()
            budget -= 1
            if self._partial.start >= len(self._partial.req.prompt):
                self._finish_prefill()
        if self.lanes:
            self.decode_burst()

    def run(self, requests: Sequence[Request]) -> Dict[Any, List[int]]:
        """Submit ``requests``, drive to completion, return rid -> tokens."""
        for r in requests:
            self.submit(r)
        while self.has_work():
            self.step()
        return {r.rid: self.outputs[r.rid] for r in requests}
