"""Admission scheduling and capture-size budgeting for the decode engine.

The jitted decode burst compiles once per (capture size, burst length)
pair, so the engine rounds the live-lane count up to a small fixed menu of
batch shapes instead of retracing on every join/evict. Powers of two up to
``max_batch`` keep the compile count logarithmic while wasting at most half
the lanes as padding.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Tuple


def capture_sizes(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch``, plus ``max_batch`` itself."""
    if max_batch < 1:
        raise ValueError("max_batch must be >= 1")
    sizes = []
    s = 1
    while s < max_batch:
        sizes.append(s)
        s *= 2
    sizes.append(max_batch)
    return tuple(sorted(set(sizes)))


def pick_capture(n: int, sizes: Tuple[int, ...]) -> int:
    """Smallest capture size >= n."""
    for s in sizes:
        if s >= n:
            return s
    raise ValueError(f"{n} live lanes exceed the largest capture size "
                     f"{sizes[-1]}")


class FifoScheduler:
    """FIFO admission queue. The engine pops a request only when both a
    decode lane and enough KV pages are available (admission backpressure);
    otherwise the request simply waits its turn."""

    def __init__(self):
        self._queue: Deque = deque()

    def submit(self, req) -> None:
        self._queue.append(req)

    def peek(self):
        return self._queue[0] if self._queue else None

    def pop(self):
        return self._queue.popleft()

    def __len__(self) -> int:
        return len(self._queue)

    def __bool__(self) -> bool:
        return bool(self._queue)
