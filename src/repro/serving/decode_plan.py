"""Cached decode-shaped CVMM plans and the expert-MLP decode provider.

Two cache levels, keyed as documented in serving/__init__.py:

* skeleton cache — routing-free ``DecodePlan`` per decode shape class,
  keyed ``(n_tokens, k, n_experts, d_model, expert_size, dtype)``. A miss
  runs the autotuner's ``decode_gemm`` family and builds the static layout
  (``kernels/ops.py:make_decode_plan``); every later step with the same
  shape reuses it, so at steady state ``rebuilds`` stays frozen while
  ``hits`` climbs. ``None`` results (no fitting tile) are cached too, so a
  shape that can't use the decode path is probed exactly once.

* assembled cache — full ``CvmmPlan`` per (skeleton, routing) pair, keyed
  by the skeleton key plus the raw bytes of (idx, gates). The hot path
  never touches it (``moe_mlp_decode`` runs straight off the skeleton);
  the serve bench and tests use it to show routing-change invalidation
  semantics against the plan-invariant oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax

from ..sharding.context import current_mesh


def _skeleton_key(n_tokens: int, k: int, n_experts: int, d_model: int,
                  expert_size: int, dtype) -> Tuple:
    return (n_tokens, k, n_experts, d_model, expert_size,
            str(jax.numpy.dtype(dtype)))


class DecodePlanCache:
    """Skeleton + assembled plan caches with spy counters.

    ``rebuilds``/``hits`` count skeleton construction vs reuse;
    ``assembles``/``assembled_hits`` do the same for routing-materialized
    plans. The CI serve gate pins ``rebuilds`` deltas to zero over the
    steady-state window.
    """

    def __init__(self):
        self._skeletons: Dict[Tuple, object] = {}
        self._assembled: Dict[Tuple, object] = {}
        self.rebuilds = 0
        self.hits = 0
        self.assembles = 0
        self.assembled_hits = 0

    def skeleton(self, n_tokens: int, k: int, n_experts: int, d_model: int,
                 expert_size: int, dtype):
        """Cached ``DecodePlan`` for one shape class (None if no tile fits)."""
        from ..kernels import ops as kops

        key = _skeleton_key(n_tokens, k, n_experts, d_model, expert_size,
                            dtype)
        if key in self._skeletons:
            self.hits += 1
            return self._skeletons[key]
        self.rebuilds += 1
        # The provider runs inside jit traces; build the skeleton's constant
        # arrays eagerly so the cached plan holds real arrays, not tracers of
        # whichever trace happened to miss first.
        with jax.ensure_compile_time_eval():
            plan = kops.make_decode_plan(n_tokens, k, n_experts, d_model,
                                         expert_size, dtype=dtype)
        self._skeletons[key] = plan
        return plan

    def assembled(self, plan, idx, gates):
        """Cached full ``CvmmPlan`` for one concrete routing (host-side:
        idx/gates must be concrete arrays, not tracers)."""
        from ..kernels import ops as kops

        idx_np = np.asarray(idx)
        key = (plan.n_tokens, plan.k, plan.n_experts, plan.cap,
               idx_np.tobytes(), np.asarray(gates).tobytes())
        if key in self._assembled:
            self.assembled_hits += 1
            return self._assembled[key]
        self.assembles += 1
        full = kops.assemble_decode_plan(plan, idx, gates)
        self._assembled[key] = full
        return full

    def counters(self) -> Dict[str, int]:
        return {"rebuilds": self.rebuilds, "hits": self.hits,
                "assembles": self.assembles,
                "assembled_hits": self.assembled_hits}


def make_provider(cache: DecodePlanCache, *, max_tokens: int = 64):
    """Build an ``expert_mlp`` decode provider backed by ``cache``.

    The provider serves the sort dispatch only for decode-sized calls
    (``n_tokens <= max_tokens``) with no active mesh; anything else returns
    None and falls through to the regular per-call plan path. Install with
    ``core.dispatch.set_decode_provider``; remove with
    ``set_decode_provider(None)``.
    """

    def provider(params, xf, cfg, info, e):
        n = int(xf.shape[0])
        if n > max_tokens or current_mesh() is not None:
            return None
        from ..core.dispatch import resolve_impl
        from ..kernels import ops as kops

        k = int(info.idx.shape[-1])
        plan = cache.skeleton(n, k, e, int(xf.shape[1]),
                              int(cfg.expert_size), xf.dtype)
        if plan is None:
            return None
        interpret = (True if resolve_impl(cfg).endswith("_interpret")
                     else None)
        w1g = params.get("we1g") if cfg.glu_experts else None
        return kops.moe_mlp_decode(
            xf, info.idx, info.gates, plan,
            params["we1"], params["we2"], w1g,
            activation=cfg.activation, interpret=interpret)

    return provider
