import os
_DEFAULT_XLA_FLAGS = "--xla_force_host_platform_device_count=512"
_PRESET_XLA_FLAGS = bool(os.environ.get("XLA_FLAGS"))
os.environ.setdefault("XLA_FLAGS", _DEFAULT_XLA_FLAGS)
# ^ MUST precede any jax-touching import: jax locks the device count on first
# init. An externally-set XLA_FLAGS wins (e.g. the CI mesh gate forces 8 host
# devices and runs the local-mesh smoke mode below); the 512-device default
# only applies when the caller set nothing.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes, prove the distribution config is coherent, and extract the
roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
        --mesh both [--sp] [--remat full] [--tag baseline] [--out benchmarks/dryrun_results]

    PYTHONPATH=src python -m repro.launch.dryrun --all          # all 40 cells

Per cell it prints compiled.memory_analysis() (fits-in-HBM evidence) and
cost_analysis(), and writes <out>/<tag>/<arch>__<shape>__<mesh>.json with the
roofline report (EXPERIMENTS.md is generated from these files).

Local-mesh smoke mode (--mesh local, the DEFAULT when XLA_FLAGS is preset in
the environment): builds a mesh from whatever devices the process actually has
— (data=n/2, model=2), plus a leading 'pod' axis with --pod — and EXECUTES a
real train step on a reduced config instead of only lowering. This is the
regression gate for the sharding-rules layer (the seed ``--ffn pkm``
duplicate-PartitionSpec crash died exactly here, in tree_shardings before any
compile) and for the EP/pod-tier wiring:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.dryrun --ffn pkm
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.dryrun --ffn sigma_moe \
        --dispatch shard_map --pod 2 --grad-compression int8
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback


def _cell_applicable(cfg, shape) -> (bool, str):
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (skip: full-attn)"
    return True, ""


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, sp: bool, remat: str,
             ce_chunks: int, dispatch: str, out_dir: str, tag: str,
             ffn: str = None, grad_accum: int = 1, verbose: bool = True):
    import jax
    import jax.numpy as jnp

    from ..configs import SHAPES, get_config
    from ..models import build_model
    from ..roofline import analyze_compiled
    from ..runtime.steps import init_train_state, make_train_step
    from ..configs.base import OptimizerConfig
    from ..sharding import TRAIN_RULES, mesh_context, tree_shardings
    from ..sharding.logical import serve_rules_for
    from .mesh import make_production_mesh

    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = _cell_applicable(cfg, shape)
    cellname = f"{arch}__{shape_name}__{mesh_kind}"
    if not ok:
        result = {"cell": cellname, "status": "skipped", "reason": why,
                  "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
        if out_dir:
            d = os.path.join(out_dir, tag)
            os.makedirs(d, exist_ok=True)
            with open(os.path.join(d, cellname + ".json"), "w") as f:
                json.dump(result, f, indent=1)
        return result

    if dispatch and cfg.ffn.kind in ("sigma_moe", "switch", "sbase", "noisy_topk"):
        cfg = cfg.with_ffn(dataclasses.replace(cfg.ffn, dispatch=dispatch))

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    model = build_model(cfg, remat=remat, sequence_parallel=sp,
                        ce_chunks=ce_chunks, ep_degree=mesh.shape["model"],
                        ffn=ffn)
    cfg = model.cfg

    t0 = time.time()
    with mesh_context(mesh):
        rules = (TRAIN_RULES if shape.mode == "train" else
                 serve_rules_for(cfg.attention.n_kv_heads, mesh.shape["model"]))

        def sds_with_shardings(tree):
            sh = tree_shardings(tree, mesh, rules)
            return jax.tree_util.tree_map(
                lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
                tree, sh)

        inputs = model.input_specs(shape)
        inputs_sds = sds_with_shardings(inputs)

        if shape.mode == "train":
            opt_cfg = OptimizerConfig()
            state = jax.eval_shape(
                lambda k: init_train_state(model, k, opt_cfg), jax.random.PRNGKey(0))
            state_sds = sds_with_shardings(state)
            step = make_train_step(model, opt_cfg, grad_accum=grad_accum)
            rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(
                state_sds, inputs_sds, rng_sds)
        elif shape.mode == "prefill":
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            params_sds = sds_with_shardings(params)
            cache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sds = sds_with_shardings(cache)
            lowered = jax.jit(model.prefill, donate_argnums=(2,)).lower(
                params_sds, inputs_sds, cache_sds)
        else:  # decode
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            params_sds = sds_with_shardings(params)
            cache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sds = sds_with_shardings(cache)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(model.decode_step, donate_argnums=(1,)).lower(
                params_sds, cache_sds, inputs_sds["token"], pos_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        report = analyze_compiled(compiled, arch=arch, shape=shape,
                                  mesh_name=mesh_kind, n_chips=n_chips, cfg=cfg)
        mem = compiled.memory_analysis()
        if verbose:
            print(f"--- {cellname} ---")
            print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
            print(f"  memory_analysis: {mem}")
            ca = report.xla_cost_analysis
            print(f"  cost_analysis (body-once): {ca}")
            print(f"  roofline: compute {report.compute_s*1e3:.2f}ms "
                  f"memory {report.memory_s*1e3:.2f}ms "
                  f"collective {report.collective_s*1e3:.2f}ms "
                  f"-> {report.bound}-bound; useful-flops "
                  f"{report.useful_flops_ratio:.2f}; roofline frac "
                  f"{report.roofline_fraction:.3f}", flush=True)

    result = dict(report.to_dict(), cell=cellname, status="ok",
                  lower_s=t_lower, compile_s=t_compile, tag=tag,
                  sp=sp, remat=remat, ce_chunks=ce_chunks, dispatch=dispatch or "",
                  grad_accum=grad_accum)
    if out_dir:
        d = os.path.join(out_dir, tag)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, cellname + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def run_local_smoke(args) -> int:
    """Execute (not just compile) train steps on a local-device mesh.

    Proves the full path end-to-end: sharding-rules setup (tree_shardings is
    where the seed PKM duplicate-axis bug crashed), dispatch (incl. the EP
    shard_map all_to_all path), and — with --pod > 1 and --grad-compression —
    the pod-tier compressed gradient reduction.
    """
    import jax
    import jax.numpy as jnp

    from ..configs import reduced
    from ..configs.base import OptimizerConfig
    from ..models import build_model
    from ..runtime.steps import init_train_state, make_train_step
    from ..sharding import TRAIN_RULES, mesh_context, tree_shardings
    from .mesh import make_local_mesh

    arch = args.arch or "wt103-47m-moe"
    mesh = make_local_mesh(model=args.model_axis, pod=args.pod)
    print(f"--- local smoke: {arch} ffn={args.ffn or 'cfg'} "
          f"dispatch={args.dispatch or 'cfg'} mesh="
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"compression={args.grad_compression} ---", flush=True)

    cfg = reduced(arch)
    if cfg.xl_memory:
        # the smoke executes stateless steps (and the pod tier rejects
        # xl_memory outright) — drop the XL memory from the reduced config.
        cfg = cfg.override(xl_memory=0)
    if args.dispatch and cfg.ffn.kind in ("sigma_moe", "switch", "sbase",
                                          "noisy_topk"):
        cfg = cfg.with_ffn(dataclasses.replace(cfg.ffn, dispatch=args.dispatch))
    model = build_model(cfg, remat=args.remat, ep_degree=mesh.shape["model"],
                        ffn=args.ffn)
    cfg = model.cfg

    pod = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
    opt_cfg = OptimizerConfig(lr=1e-3, total_steps=max(args.steps, 2),
                              grad_compression=args.grad_compression)
    batch_size = 8 * pod
    seq = 32
    with mesh_context(mesh):
        state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg, pod=pod)
        shardings = tree_shardings(state, mesh, TRAIN_RULES)
        state = jax.device_put(state, shardings)
        step_fn = jax.jit(make_train_step(model, opt_cfg, mesh=mesh),
                          donate_argnums=(0,))
        rng = jax.random.PRNGKey(1)
        t0 = time.time()
        for s in range(args.steps):
            tokens = jax.random.randint(jax.random.fold_in(rng, s),
                                        (batch_size, seq + 1), 0, cfg.vocab_size)
            state, metrics = step_fn(state, {"tokens": tokens}, rng)
            loss = float(metrics["loss"])
            if not (loss == loss):            # NaN guard
                print(f"step {s}: loss is NaN", flush=True)
                return 1
            print(f"step {s} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
    print(f"LOCAL SMOKE OK ({args.steps} executed step(s), "
          f"{time.time() - t0:.1f}s)", flush=True)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None,
                    choices=["single", "multi", "both", "local"],
                    help="production mesh kind, or 'local' to execute a train-"
                         "step smoke on this process's devices (the default "
                         "when XLA_FLAGS is preset in the environment)")
    ap.add_argument("--all", action="store_true", help="all (arch x shape) cells")
    ap.add_argument("--sp", action="store_true", help="sequence-parallel residuals")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--ce-chunks", type=int, default=16)
    ap.add_argument("--dispatch", default="", help="override MoE dispatch path")
    ap.add_argument("--ffn", default=None, help="swap FFN kind (e.g. sigma_moe)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--skip-existing", action="store_true")
    # local smoke mode knobs
    ap.add_argument("--steps", type=int, default=1,
                    help="local mode: number of train steps to EXECUTE")
    ap.add_argument("--model-axis", type=int, default=2,
                    help="local mode: size of the 'model' mesh axis")
    ap.add_argument("--pod", type=int, default=1,
                    help="local mode: size of the DCN 'pod' axis (pod-tier "
                         "gradient compression engages with --grad-compression)")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args(argv)

    if args.mesh is None:
        # An externally forced device count means the caller wants a smoke on
        # THAT topology, not the 512-device production lowering sweep.
        args.mesh = "local" if _PRESET_XLA_FLAGS else "single"
    if args.mesh == "local":
        return run_local_smoke(args)

    from ..configs import ASSIGNED_ARCHS, SHAPES

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                cell = f"{arch}__{shape}__{mesh}"
                path = os.path.join(args.out, args.tag, cell + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {cell}")
                    continue
                try:
                    r = run_cell(arch, shape, mesh, sp=args.sp, remat=args.remat,
                                 ce_chunks=args.ce_chunks, dispatch=args.dispatch,
                                 out_dir=args.out, tag=args.tag, ffn=args.ffn,
                                 grad_accum=args.grad_accum)
                    if r["status"] == "skipped":
                        print(f"[skipped] {cell}: {r['reason']}")
                except Exception as e:
                    traceback.print_exc()
                    failures.append((cell, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for c, e in failures:
            print(f"  {c}: {e[:200]}")
        return 1
    print("\nALL CELLS OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
