"""Production mesh construction.

Functions (not module constants) so importing never touches jax device state --
required because dryrun.py must set XLA_FLAGS before the first jax init.
"""
from __future__ import annotations

from typing import Tuple

import jax

# Every mesh axis layout this repo constructs (production, local, tests). The
# sharding-table analyzer (repro.analysis.sharding) sweeps PARAM_AXES x rule
# sets against each of these, so a rule that maps two dims of one leaf onto
# the same mesh axis is caught offline for every layout we can ever run on —
# not just the one a particular test happens to build. Keep in sync with the
# constructors below (they assert against this table).
MESH_AXIS_LAYOUTS: Tuple[Tuple[str, ...], ...] = (
    ("data", "model"),            # single pod / local default
    ("pod", "data", "model"),     # multi-pod: leading DCN axis
)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod adds the DCN 'pod'
    axis: (pod=2, data=16, model=16) = 512 chips.

    When the process exposes more devices than the mesh needs (the dry-run forces
    512 host devices and then builds the 256-chip single-pod mesh), the first
    prod(shape) devices are used.
    """
    import math
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = MESH_AXIS_LAYOUTS[1] if multi_pod else MESH_AXIS_LAYOUTS[0]
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices for mesh {shape}, have {len(devs)} "
                           "(dry-run must set xla_force_host_platform_device_count)")
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, pod: int = 1):
    """Whatever this host has: (data=n/(pod*model), model), with a leading DCN
    'pod' axis when pod > 1 -- used by tests/examples/local dry-runs.

    Raises when the requested axis sizes do not tile the device count: the old
    behavior silently built a (n//model, model) mesh that DROPPED devices (8
    devices, model=3 -> a 6-device mesh with 2 chips idle).
    """
    n = len(jax.devices())
    if model < 1 or pod < 1:
        raise ValueError(f"mesh axis sizes must be >= 1, got model={model} pod={pod}")
    if n % (model * pod):
        divisors = [d for d in range(1, n + 1) if n % d == 0]
        raise ValueError(
            f"make_local_mesh: model={model} * pod={pod} does not divide the "
            f"device count {n} — a (n//model, model) mesh would silently drop "
            f"{n - (n // (model * pod)) * model * pod} device(s). Pick axis "
            f"sizes whose product divides {n} (divisors: {divisors}).")
    data = n // (model * pod)
    if pod > 1:
        return jax.make_mesh((pod, data, model), MESH_AXIS_LAYOUTS[1])
    return jax.make_mesh((data, model), MESH_AXIS_LAYOUTS[0])
