"""Production mesh construction.

Functions (not module constants) so importing never touches jax device state --
required because dryrun.py must set XLA_FLAGS before the first jax init.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod adds the DCN 'pod'
    axis: (pod=2, data=16, model=16) = 512 chips.

    When the process exposes more devices than the mesh needs (the dry-run forces
    512 host devices and then builds the 256-chip single-pod mesh), the first
    prod(shape) devices are used.
    """
    import math
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices for mesh {shape}, have {len(devs)} "
                           "(dry-run must set xla_force_host_platform_device_count)")
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has: (data=n/model, model) -- used by tests/examples."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
