"""Fault-tolerant training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch wt103-47m-moe --steps 200 \
        --batch 8 --seq 128 --mesh 1x1 [--resume] [--data synthetic|/path/corpus]

Fault-tolerance posture (exercised by tests/test_fault_tolerance.py):
  * every state leaf (params, optimizer, error-feedback, XL mems, data-iterator
    state, RNG) lives in ONE checkpointed pytree -> restart is bit-exact;
  * checkpoints are atomic + async (CheckpointManager); SIGTERM/preemption between
    commits loses at most `checkpoint_every` steps;
  * the step loop tolerates transient compute errors by restoring the last
    checkpoint (restart-in-place) before re-raising persistent ones;
  * straggler monitor flags slow steps for the orchestrator.

XLA flags for compute/comm overlap on TPU are set by `tpu_perf_flags()` -- latency
hiding scheduler + async collectives (a no-op on CPU).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def tpu_perf_flags() -> str:
    return " ".join([
        "--xla_tpu_enable_latency_hiding_scheduler=true",
        "--xla_tpu_megacore_fusion_allow_ags=true",
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    ])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="wt103-47m-moe")
    ap.add_argument("--ffn", default=None,
                    help="swap FFN kind (sigma_moe|topk|pkm|dense)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=2.5e-4)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config of the arch")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="TESTING: raise at this step to exercise restart")
    args = ap.parse_args(argv)

    if "tpu" in os.environ.get("JAX_PLATFORMS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " +
                                   tpu_perf_flags())

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..checkpoint import CheckpointManager
    from ..configs import OptimizerConfig, get_config, reduced
    from ..data import DataIterator, make_dataset
    from ..models import build_model
    from ..runtime.monitor import StragglerMonitor
    from ..runtime.steps import init_train_state, make_train_step
    from ..sharding import TRAIN_RULES, mesh_context, tree_shardings
    from .mesh import make_mesh

    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model")[: len(dshape)] if len(dshape) == 2
                     else ("pod", "data", "model"))

    cfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg, remat=args.remat,
                        ep_degree=mesh.shape.get("model", 1),
                        ffn=args.ffn)
    cfg = model.cfg

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              grad_accum=args.grad_accum,
                              grad_compression=args.grad_compression)
    # Mesh-aware step: with a 'pod' axis of size > 1 and compression on, the
    # expert-gradient all-reduce over the DCN tier goes through per-pod
    # error-feedback quantization (runtime/steps.py pod tier) instead of a
    # host-local roundtrip.
    pod = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
    if pod > 1 and args.grad_compression != "none":
        print(f"[mesh] pod tier active: {args.grad_compression} error-feedback "
              f"compression on the expert subtree across pod={pod}", flush=True)
    train_step = make_train_step(model, opt_cfg, grad_accum=args.grad_accum,
                                 mesh=mesh)

    ds = make_dataset(args.data, cfg.vocab_size)
    it = DataIterator(ds, args.batch, args.seq + 1, seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir, keep=args.keep)
    mon = StragglerMonitor(on_straggler=lambda s, dt, mu: print(
        f"[straggler] step {s}: {dt:.3f}s vs mean {mu:.3f}s", flush=True))

    with mesh_context(mesh):
        key = jax.random.PRNGKey(args.seed)
        state = init_train_state(model, key, opt_cfg, use_mems=bool(cfg.xl_memory),
                                 batch=args.batch, pod=pod)
        shardings = tree_shardings(state, mesh, TRAIN_RULES)
        state = jax.device_put(state, shardings)

        start_step = 0
        if args.resume:
            restored, extra = mgr.restore(state, shardings=shardings)
            if restored is not None:
                state = restored
                start_step = int(extra["step"])
                it.restore(extra["data"])
                print(f"[resume] restored step {start_step}", flush=True)

        step_fn = jax.jit(train_step, donate_argnums=(0,))
        rng = jax.random.PRNGKey(args.seed + 1)

        t_start = time.time()
        try:
            for step in range(start_step, args.steps):
                if step == args.fail_at_step:
                    raise RuntimeError(f"injected failure at step {step}")
                batch = {k: jnp.asarray(v) for k, v in it.next().items()}
                mon.start()
                state, metrics = step_fn(state, batch, rng)
                if step % args.log_every == 0 or step == args.steps - 1:
                    loss = float(metrics["loss"])
                    dt = mon.stop(step)
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.3f} {dt:.3f}s",
                          flush=True)
                else:
                    jax.block_until_ready(metrics["loss"])
                    mon.stop(step)
                if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                    mgr.save(step + 1, state, extra={"data": it.state()})
        except BaseException:
            # Preemption/crash path: an async save started before the failure
            # must still commit, or "loses at most ckpt_every steps" is a lie —
            # the daemon writer thread dies with the process mid-write.
            mgr.wait()
            raise
        mgr.save(args.steps, state, extra={"data": it.state()}, blocking=True)
        mgr.wait()
        total = time.time() - t_start
        print(f"[done] {args.steps - start_step} steps in {total:.1f}s "
              f"({(args.steps - start_step) / max(total, 1e-9):.2f} it/s); "
              f"stragglers={len(mon.flagged)}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
