"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the dry-run
result JSONs. §Perf narrative is maintained by hand in EXPERIMENTS.md; this script
rewrites only the generated blocks between the AUTOGEN markers.

    PYTHONPATH=src python scripts/gen_experiments.py
"""
import glob
import json
import os
import sys

OUT = "EXPERIMENTS.md"
RESULTS = "benchmarks/dryrun_results"

SUGGEST = {
    ("memory", "decode"): "decode is weight/cache-bandwidth bound: quantize weights/KV (int8) or raise batch to amortize reads",
    ("memory", "train"): "cut recompute (remat=dots) and fuse elementwise chains; shard activations over model (SP)",
    ("memory", "prefill"): "KV-cache write/read traffic dominates: keep cache bf16, shard seq over TP, fuse rope+write",
    ("collective", "train"): "reduce dispatch/FSDP all-gathers: shard_map a2a MoE dispatch, overlap collectives with compute",
    ("collective", "prefill"): "resharding between attention/FFN layouts: align layouts to avoid gather/a2a per layer",
    ("collective", "decode"): "per-token all-reduces dominate: batch layers' reductions, use 1D TP collective schedule",
    ("compute", "train"): "near compute bound: chase MXU utilization (tile alignment, bf16, larger per-chip batch)",
    ("compute", "prefill"): "compute bound: good; increase per-chip work or overlap collectives to approach peak",
    ("compute", "decode"): "compute bound at decode is unusual: check routing/gather overhead",
}


def fmt_bytes(b):
    return f"{b/1e9:.2f}GB"


def load(tag):
    rows = {}
    for f in glob.glob(os.path.join(RESULTS, tag, "*.json")):
        r = json.load(open(f))
        rows[r["cell"]] = r
    return rows


def dryrun_table(rows):
    lines = ["| cell | status | per-dev arg+temp bytes | HLO GFLOPs/dev | wire GB/dev | collectives | compile s |",
             "|---|---|---|---|---|---|---|"]
    for cell in sorted(rows):
        r = rows[cell]
        if r.get("status") == "skipped":
            lines.append(f"| {cell} | SKIP: {r['reason']} | | | | | |")
            continue
        ma = r.get("memory_analysis", {})
        per_dev = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0))
        nc = sum(1 for _ in r.get("coll_by_kind", {}))
        kinds = ",".join(f"{k.replace('all-','a')}:{fmt_bytes(v)}"
                         for k, v in sorted(r.get("coll_by_kind", {}).items()))
        lines.append(
            f"| {cell} | ok | {fmt_bytes(per_dev)} | {r['flops']/1e9:.1f} | "
            f"{r['coll_bytes']/1e9:.2f} | {kinds} | {r.get('compile_s',0):.0f} |")
    return "\n".join(lines)


def roofline_table(rows):
    lines = ["| arch | shape | compute s | memory s | collective s | bound | MODEL GFLOPs | useful ratio | roofline frac | what moves the bound |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for cell in sorted(rows):
        r = rows[cell]
        if r.get("status") == "skipped" or r["mesh"] != "single":
            continue
        mode = ("train" if "train" in r["shape"] else
                "prefill" if "prefill" in r["shape"] else "decode")
        sug = SUGGEST.get((r["bound"], mode), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | **{r['bound']}** | "
            f"{r['model_flops_global']/1e9:.0f} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {sug} |")
    skip = [f"{r['arch']}/{r['shape']}" for r in rows.values()
            if r.get("status") == "skipped" and r.get("cell", "").endswith("single")]
    if skip:
        lines.append("")
        lines.append(f"Skipped (per DESIGN.md §3): {', '.join(sorted(set(skip)))}")
    return "\n".join(lines)


def replace_block(text, marker, content):
    start = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- /AUTOGEN:{marker} -->"
    if start not in text:
        return text + f"\n{start}\n{content}\n{end}\n"
    pre = text.split(start)[0]
    post = text.split(end)[1]
    return pre + start + "\n" + content + "\n" + end + post


def perf_variants_table():
    tags = [t for t in sorted(os.listdir(RESULTS))
            if os.path.isdir(os.path.join(RESULTS, t))]
    by_cell = {}
    for tag in tags:
        for cell, r in load(tag).items():
            if r.get("status") != "ok":
                continue
            by_cell.setdefault(cell, []).append((tag, r))
    lines = ["### Perf-variant measurements (all tags, generated)",
             "",
             "| cell | tag | compute s | memory s | collective s | bound | useful | frac | HBM GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for cell in sorted(by_cell):
        if len(by_cell[cell]) < 2:
            continue
        for tag, r in sorted(by_cell[cell]):
            ma = r.get("memory_analysis", {})
            hbm = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)) / 1e9
            lines.append(
                f"| {cell} | {tag} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                f"{r['collective_s']:.3f} | {r['bound']} | "
                f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
                f"{hbm:.1f} |")
    return "\n".join(lines)


def main():
    rows = load("baseline")
    text = open(OUT).read() if os.path.exists(OUT) else "# EXPERIMENTS\n"
    text = replace_block(text, "dryrun", dryrun_table(rows))
    text = replace_block(text, "roofline", roofline_table(rows))
    text = replace_block(text, "perf_variants", perf_variants_table())
    open(OUT, "w").write(text)
    print(f"wrote {OUT}: {len(rows)} cells")


if __name__ == "__main__":
    main()
