"""Batched serving: prefill a batch of prompts, then step a shared decode loop with
per-request completion tracking (continuous-batching lite).

    PYTHONPATH=src python examples/serve_batched.py --arch llama3-8b --reduced
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    b, p_len = args.batch, args.prompt_len
    max_len = p_len + args.max_new
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, p_len), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    cache = model.init_cache(b, max_len)
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    eos = 0   # pretend token 0 is EOS
    done = np.zeros(b, bool)
    outs = [[] for _ in range(b)]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    steps = 0
    for i in range(args.max_new):
        for j in range(b):
            if not done[j]:
                outs[j].append(int(tok[j]))
                if int(tok[j]) == eos:
                    done[j] = True
        if done.all():
            break
        logits, cache = decode(params, cache, tok, jnp.int32(p_len + i))
        if args.temperature > 0:
            logits = logits / args.temperature
            tok = jax.random.categorical(jax.random.fold_in(
                jax.random.PRNGKey(2), i), logits).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        steps += 1
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} prefill {t_prefill*1e3:.1f}ms; "
          f"{steps} decode steps @ {dt/max(steps,1)*1e3:.1f} ms/step "
          f"({b*steps/max(dt,1e-9):.1f} tok/s aggregate)")
    for j, o in enumerate(outs):
        print(f"req{j}: {o}")


if __name__ == "__main__":
    main()
