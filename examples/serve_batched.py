"""Batched serving through the continuous-batching engine (repro.serving).

Requests with different prompt lengths and generation budgets join and
leave the decode batch mid-flight; EOS/length termination is decided on
device inside jitted decode bursts (no per-step host sync, one readback
per burst), and the KV cache is paged so join/evict never reshapes device
state.

    PYTHONPATH=src python examples/serve_batched.py --arch llama3-8b --reduced
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--burst-steps", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    eos = 0   # pretend token 0 is EOS
    reqs = []
    for j in range(args.batch):
        p_len = int(rng.integers(max(2, args.prompt_len // 2),
                                 args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=p_len).tolist()
        reqs.append(Request(rid=f"req{j}", prompt=prompt,
                            max_new=args.max_new, eos=eos))

    max_len = args.prompt_len + args.max_new
    with Engine(model, params, max_batch=args.batch, max_len=max_len,
                page_size=args.page_size, burst_steps=args.burst_steps) as eng:
        t0 = time.perf_counter()
        outs = eng.run(reqs)
        dt = time.perf_counter() - t0
        n_tok = sum(len(o) for o in outs.values())
        print(f"arch={cfg.name} {n_tok} tokens in {dt*1e3:.1f}ms "
              f"({n_tok/max(dt, 1e-9):.1f} tok/s aggregate); "
              f"stats={eng.stats}")
        if eng.plan_cache is not None:
            print(f"decode-plan cache: {eng.plan_cache.counters()}")
    for j in range(args.batch):
        print(f"req{j}: {outs[f'req{j}']}")


if __name__ == "__main__":
    main()
