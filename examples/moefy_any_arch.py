"""sigma-MoE as a drop-in: take ANY assigned architecture and swap its FFN for a
parameter-matched sigma-MoE (the paper's central claim — the technique is generic).

    PYTHONPATH=src python examples/moefy_any_arch.py --arch llama3-8b --steps 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.configs.base import OptimizerConfig
from repro.data import DataIterator, make_dataset
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step


def train(model, steps, seed=0):
    opt = OptimizerConfig(lr=3e-3, total_steps=steps)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(seed), opt)
    it = DataIterator(make_dataset("synthetic", model.cfg.vocab_size), 8, 65,
                      seed=seed)
    rng = jax.random.PRNGKey(seed + 1)
    last = None
    for _ in range(steps):
        state, m = step(state, {"tokens": jnp.asarray(it.next()["tokens"])}, rng)
        last = float(m["loss"])
    return last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = reduced(args.arch)
    base = build_model(cfg)
    moe = build_model(cfg, ffn="sigma_moe")
    print(f"{args.arch}: original ffn={cfg.ffn.kind} "
          f"-> moefied ffn={moe.cfg.ffn.kind} "
          f"(N_E={moe.cfg.ffn.n_experts}, G={moe.cfg.ffn.expert_size}, "
          f"K={moe.cfg.ffn.k})")
    lb = train(base, args.steps)
    lm_ = train(moe, args.steps)
    print(f"loss after {args.steps} steps: original {lb:.4f}  sigma-moe {lm_:.4f}")


if __name__ == "__main__":
    main()
