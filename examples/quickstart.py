"""Quickstart: build a small sigma-MoE LM, train a few steps, sample from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import moe_ffn
from repro.configs.base import AttentionConfig, ModelConfig, OptimizerConfig
from repro.data import DataIterator, make_dataset
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step


def main():
    # A 16-expert sigma-MoE with K=4 (the paper's flagship config, scaled down):
    # 25% of the dense FFN FLOPs at equal parameter count.
    cfg = ModelConfig(
        name="quickstart-moe", family="moe", n_layers=4, d_model=128,
        vocab_size=256, norm="layernorm", pos_encoding="rope",
        attention=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=16,
                                  kv_chunk=128),
        ffn=moe_ffn(16, 32, 4, reg_gamma=1e-3, reg_kind="entropy",
                    expert_dropout=0.05, dispatch="sort"),
        tie_embeddings=True)
    print(f"params: {cfg.param_counts()['total']/1e6:.2f}M "
          f"(active {cfg.param_counts()['active']/1e6:.2f}M)")

    model = build_model(cfg)
    opt = OptimizerConfig(lr=3e-3, total_steps=60)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    it = DataIterator(make_dataset("synthetic", 256), 8, 65, seed=0)
    rng = jax.random.PRNGKey(1)
    for s in range(60):
        state, m = step(state, {"tokens": jnp.asarray(it.next()["tokens"])}, rng)
        if s % 10 == 0 or s == 59:
            print(f"step {s:3d}  loss {float(m['loss']):.4f}  "
                  f"moe_reg {float(m['moe_reg']):+.5f}")

    # greedy sampling with the KV cache
    params = state["params"]
    prompt = jnp.asarray(it.next()["tokens"])[:1, :16]
    cache = model.init_cache(1, 48)
    logits, cache = model.prefill(params, {"tokens": prompt}, cache)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(16):
        toks.append(int(tok[0]))
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.int32(16 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print("prompt:", prompt[0].tolist())
    print("continuation:", toks)


if __name__ == "__main__":
    main()
