"""The paper's headline experiment as a script: parameter-matched dense vs
sigma-MoE, trained side by side (paper Tab. 3 at reduced scale).

    PYTHONPATH=src python examples/dense_vs_moe.py --steps 150
"""
import argparse

from benchmarks.common import tiny_lm, train_variant
from repro.configs import moe_ffn
from repro.configs.base import FFNConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=64)
    args = ap.parse_args()

    dense = FFNConfig(kind="dense", d_ff=256, activation="relu")
    smoe = moe_ffn(8, 32, 2, reg_gamma=1e-3, reg_kind="entropy",
                   expert_dropout=0.05, dispatch="sort")

    rd = train_variant("dense", tiny_lm(dense, d_model=args.d_model),
                       steps=args.steps)
    rm = train_variant("sigma_moe", tiny_lm(smoe, d_model=args.d_model),
                       steps=args.steps)
    print(f"{'variant':12s} {'params':>9s} {'ffn FLOPs':>9s} {'final loss':>10s}")
    for r in (rd, rm):
        print(f"{r['name']:12s} {r['params']:9d} {r['ffn_flops_pct']:8.1f}% "
              f"{r['final_loss']:10.4f}")
    gap = rm["final_loss"] - rd["final_loss"]
    print(f"\nsigma-MoE vs dense loss gap: {gap:+.4f} "
          f"(paper: MoE matches dense at 25% FFN compute)")


if __name__ == "__main__":
    main()
