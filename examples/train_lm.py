"""End-to-end training driver: the paper's WikiText-103 47M sigma-MoE Transformer-XL
with checkpointing, resume, straggler monitoring, and mesh sharding.

Full paper config (defaults):
    PYTHONPATH=src python examples/train_lm.py --steps 300

CI-sized preset:
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 60

This wraps the production launcher (repro.launch.train) -- the same entrypoint a
cluster job would invoke -- pinned to the paper-faithful configuration. Compare the
dense baseline with --arch wt103-47m-dense: parameter counts match (47.2M), the MoE
runs 25% of the FFN FLOPs (paper Tab. 3).
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="wt103-47m-moe")
    ap.add_argument("--preset", choices=["paper", "tiny"], default="paper")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' or a path to a raw text corpus "
                         "(byte-level, enwik8-style)")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--data", args.data, "--log-every", "10"]
    if args.preset == "tiny":
        argv += ["--reduced", "--batch", "8", "--seq", "64"]
    else:
        # paper Tab. 8: ctx 256, batch 64 (scaled to fit the local host)
        argv += ["--batch", "8", "--seq", "256", "--grad-accum", "2"]
    if args.resume:
        argv += ["--resume"]
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
